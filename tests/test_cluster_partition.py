"""Cluster partition/layout invariants: every doc id lands in exactly
one shard (both policies), build/rebalance preserve the corpus, and the
store-format validation satellites (DESIGN.md §5.1)."""
import json
import logging
import os

import numpy as np
import pytest

from hypothesis_compat import given, settings, strategies as st

from repro.cluster import (HashPartitioner, RangePartitioner,
                           ShardedStore, build_sharded_store, from_spec,
                           make_partitioner, rebalance)
from repro.storage import FlashStore, StoreFormatError


def _docs(n, vocab=500, seed=0, start_id=0, stride=1):
    rng = np.random.default_rng(seed)
    return [(start_id + i * stride,
             sorted((int(w), int(rng.integers(1, 20))) for w in
                    rng.choice(vocab, int(rng.integers(1, 12)),
                               replace=False)))
            for i in range(n)]


# ---------------------------------------------------------------------------
# partitioner properties
# ---------------------------------------------------------------------------
@settings(max_examples=30)
@given(ids=st.lists(st.integers(0, 1 << 40), min_size=0, max_size=50),
       n_shards=st.integers(1, 7),
       policy=st.sampled_from(["hash", "range"]))
def test_every_id_lands_in_exactly_one_shard(ids, n_shards, policy):
    """shard_of is a total function into [0, n_shards) and deterministic
    — the 'exactly one shard' invariant for both policies."""
    part = make_partitioner(policy, n_shards, doc_ids=ids)
    assert part.n_shards == n_shards
    arr = np.asarray(ids, np.int64)
    a = part.shard_of(arr)
    assert a.shape == arr.shape
    if arr.size:
        assert a.min() >= 0 and a.max() < n_shards
    # deterministic: same ids -> same shards, element-wise and rebuilt
    np.testing.assert_array_equal(a, part.shard_of(arr))
    np.testing.assert_array_equal(a, from_spec(part.spec()).shard_of(arr))
    for i, d in enumerate(ids):
        assert int(part.shard_of([d])[0]) == int(a[i])


@settings(max_examples=20)
@given(ids=st.lists(st.integers(0, 10_000), min_size=2, max_size=60,
                    unique_by=lambda x: x),
       n_shards=st.integers(1, 6))
def test_range_partitioner_is_order_preserving(ids, n_shards):
    part = RangePartitioner.fit(ids, n_shards)
    s = part.shard_of(np.sort(np.asarray(ids, np.int64)))
    assert (np.diff(s) >= 0).all()          # monotone in doc id
    assert s.min() >= 0 and s.max() < n_shards


def test_hash_partitioner_balances_sequential_ids():
    part = HashPartitioner(8)
    counts = np.bincount(part.shard_of(np.arange(8000)), minlength=8)
    assert counts.min() > 0.5 * counts.mean()   # avalanche, not id % 8


def test_partitioner_rejects_negative_and_bad_policy():
    with pytest.raises(ValueError):
        HashPartitioner(4).shard_of([-1])
    with pytest.raises(ValueError):
        make_partitioner("mod", 4)
    with pytest.raises(ValueError):
        make_partitioner("range", 4)            # needs doc_ids
    with pytest.raises(ValueError):
        HashPartitioner(0)
    with pytest.raises(ValueError):
        RangePartitioner([5, 3])                # not ascending


# ---------------------------------------------------------------------------
# build / rebalance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["hash", "range"])
def test_build_partitions_docs_disjointly(tmp_path, policy):
    docs = _docs(120, seed=1, stride=3)
    all_ids = {d for d, _ in docs}
    cl = build_sharded_store(str(tmp_path / policy), docs, n_shards=5,
                             replicas=2, policy=policy, vocab_size=512,
                             docs_per_segment=16)
    seen = []
    for s in range(cl.n_shards):
        # scan via segment decode to keep doc payloads too
        shard_docs = []
        store = cl.store(s, 0)
        for e in store.entries:
            shard_docs.extend(store.segment(e.name).docs())
        ids0 = [d for d, _ in shard_docs]
        assert len(ids0) == len(set(ids0))
        seen.extend(ids0)
        # replica 1 is an identical copy
        rep1 = []
        store1 = cl.store(s, 1)
        for e in store1.entries:
            rep1.extend(store1.segment(e.name).docs())
        assert rep1 == shard_docs
        # placement agrees with the manifest's partitioner
        if ids0:
            np.testing.assert_array_equal(
                cl.partitioner.shard_of(np.asarray(ids0)), s)
    assert sorted(seen) == sorted(all_ids)      # exactly-once placement
    cl.close()


def test_build_with_empty_shards_ok(tmp_path):
    docs = _docs(3, seed=2)
    cl = build_sharded_store(str(tmp_path / "c"), docs, n_shards=6,
                             policy="hash", vocab_size=512)
    per_shard = [s["n_docs"] for s in cl.manifest["shards"]]
    assert sum(per_shard) == 3 and 0 in per_shard
    assert cl.n_docs == 3
    cl.close()


def test_rebalance_preserves_corpus_and_swaps_generation(tmp_path):
    root = str(tmp_path / "c")
    docs = _docs(90, seed=3)
    cl = build_sharded_store(root, docs, n_shards=3, replicas=1,
                             policy="hash", vocab_size=512,
                             docs_per_segment=8)
    before = sorted(d for st_ in [cl] for s in range(cl.n_shards)
                    for d, _ in _shard_docs(cl, s))
    plan = cl.stats()
    assert sum(st_.n_docs for st_ in plan) == 90
    cl.close()

    cl2 = rebalance(root, n_shards=5, policy="range", replicas=2)
    assert cl2.generation == 1
    assert cl2.n_shards == 5 and cl2.replicas == 2
    assert not os.path.exists(os.path.join(root, "gen-000"))
    after = sorted(d for s in range(cl2.n_shards)
                   for d, _ in _shard_docs(cl2, s))
    assert after == before
    # range policy: shards hold contiguous, ordered id ranges
    prev_max = -1
    for s in range(cl2.n_shards):
        ids = [d for d, _ in _shard_docs(cl2, s)]
        if not ids:
            continue
        assert min(ids) > prev_max
        prev_max = max(ids)
    cl2.close()


def _shard_docs(cl, s):
    store = cl.store(s, 0)
    out = []
    for e in store.entries:
        out.extend(store.segment(e.name).docs())
        store.release(e.name)
    return out


# ---------------------------------------------------------------------------
# format validation satellites (FlashStore + ShardedStore)
# ---------------------------------------------------------------------------
def test_flashstore_open_rejects_non_store(tmp_path):
    with pytest.raises(StoreFormatError, match="MANIFEST.json"):
        FlashStore.open(str(tmp_path))


def test_flashstore_open_rejects_foreign_manifest(tmp_path):
    p = tmp_path / "MANIFEST.json"
    p.write_text(json.dumps({"hello": "world"}))
    with pytest.raises(StoreFormatError, match="foreign"):
        FlashStore.open(str(tmp_path))
    assert str(p.parent) in str(_raises(FlashStore.open, str(tmp_path)))


def test_flashstore_open_rejects_garbled_and_stale(tmp_path):
    (tmp_path / "MANIFEST.json").write_text("{not json")
    with pytest.raises(StoreFormatError, match="not valid JSON"):
        FlashStore.open(str(tmp_path))
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=64)
    store.manifest["version"] = 99
    store._write_manifest()
    with pytest.raises(StoreFormatError, match="version"):
        FlashStore.open(str(tmp_path / "s"))
    store.manifest["version"] = 1
    del store.manifest["docs_per_segment"]
    store._write_manifest()
    with pytest.raises(StoreFormatError, match="missing keys"):
        FlashStore.open(str(tmp_path / "s"))


def test_flashstore_open_accepts_pre_magic_manifest(tmp_path):
    """Stores written before the magic key existed (version 1, all
    required keys) must still open — data on disk stays readable."""
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=64)
    store.append_docs([(0, [(1, 2)])])
    del store.manifest["magic"]
    store._write_manifest()
    store.close()
    reopened = FlashStore.open(str(tmp_path / "s"))
    assert reopened.n_docs == 1
    reopened.close()


def test_crashed_rebalance_leftovers_are_cleared(tmp_path):
    """Stale gen-NNN trees from a crash on either side of a previous
    rebalance's manifest swap must not break or bloat the next one."""
    root = str(tmp_path / "c")
    cl = build_sharded_store(root, _docs(30, seed=6), n_shards=2,
                             vocab_size=512, docs_per_segment=8)
    # pre-commit crash: gen-001 partially written, manifest still gen 0
    FlashStore.create(os.path.join(root, "gen-001", "shard-00", "rep-0"),
                      vocab_size=512)
    # post-commit crash of some older attempt: unreferenced gen tree
    FlashStore.create(os.path.join(root, "gen-899", "shard-00", "rep-0"),
                      vocab_size=512)
    cl.rebalance(n_shards=3)
    assert cl.generation == 1 and cl.n_shards == 3
    assert sum(s["n_docs"] for s in cl.manifest["shards"]) == 30
    assert not os.path.exists(os.path.join(root, "gen-899"))
    assert sorted(fn for fn in os.listdir(root)
                  if fn.startswith("gen-")) == ["gen-001"]
    cl.close()


def test_sharded_store_open_validates(tmp_path):
    with pytest.raises(StoreFormatError, match="CLUSTER.json"):
        ShardedStore.open(str(tmp_path))
    (tmp_path / "CLUSTER.json").write_text(json.dumps({"magic": "nope"}))
    with pytest.raises(StoreFormatError, match="foreign"):
        ShardedStore.open(str(tmp_path))
    cl = build_sharded_store(str(tmp_path / "c"), _docs(5), n_shards=2,
                             vocab_size=512)
    cl.manifest["version"] = 7
    from repro.cluster.store import _write_manifest
    _write_manifest(cl.root, cl.manifest)
    with pytest.raises(StoreFormatError, match="version"):
        ShardedStore.open(cl.root)
    cl.close()


def _raises(fn, *args):
    try:
        fn(*args)
    except Exception as e:
        return e
    raise AssertionError("did not raise")


# ---------------------------------------------------------------------------
# stats / compact satellites
# ---------------------------------------------------------------------------
def test_store_stats_without_mmap(tmp_path):
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=10)
    docs = _docs(25, seed=4)
    store.append_docs(docs)
    st_ = store.stats()
    assert st_.n_segments == 3
    assert st_.n_docs == 25
    assert st_.filter_kind == "bitmap"          # auto resolved to actual
    assert st_.n_bytes == sum(
        os.path.getsize(os.path.join(store.root, e["name"]))
        for e in store.manifest["segments"])
    assert st_.n_items == sum(e["n_items"]
                              for e in store.manifest["segments"])
    assert not store._open_segments              # nothing was mmapped
    store.close()


def test_empty_store_stats(tmp_path):
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512)
    st_ = store.stats()
    assert st_.n_segments == 0 and st_.n_docs == 0 and st_.n_bytes == 0
    store.close()


def test_compact_logs_orphans(tmp_path, caplog):
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=512,
                              docs_per_segment=8)
    store.append_docs(_docs(12, seed=5))
    orphan = os.path.join(store.root, "seg-999999.rsps")
    real = os.path.join(store.root, store.manifest["segments"][0]["name"])
    with open(orphan, "wb") as f, open(real, "rb") as g:
        f.write(g.read())                       # crashed-append leftover
    with caplog.at_level(logging.INFO, logger="repro.storage.store"):
        store.compact()
    assert not os.path.exists(orphan)
    assert any("orphan" in r.message and "seg-999999.rsps" in r.message
               for r in caplog.records)
    # compacted store still reads back whole
    assert store.stats().n_docs == 12
    store.close()
