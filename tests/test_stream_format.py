"""Paper Fig. 8 stream format: roundtrip + bandwidth-saving claim."""
import numpy as np
from hypothesis_compat import given, settings, strategies as st

from repro.core import stream_format as sf


docs_strategy = st.lists(
    st.tuples(
        st.integers(0, sf.MAX_DOC_ID),
        st.lists(st.tuples(st.integers(0, sf.KEY_MASK),
                           st.integers(0, sf.VAL_MASK)),
                 min_size=0, max_size=30, unique_by=lambda p: p[0]),
    ),
    min_size=0, max_size=20, unique_by=lambda d: d[0],
)


@settings(max_examples=50, deadline=None)
@given(docs=docs_strategy)
def test_roundtrip(docs):
    stream = sf.encode(docs)
    back = sf.decode(stream)
    want = [(d, sorted(p)) for d, p in docs]
    got = [(d, sorted(p)) for d, p in back]
    assert got == want


@settings(max_examples=30, deadline=None)
@given(docs=docs_strategy)
def test_decode_to_ell_matches_decode(docs):
    stream = sf.encode(docs)
    doc_ids, ids, vals, norms, n_trunc = sf.decode_to_ell(stream, nnz_pad=32)
    back = dict(sf.decode(stream))
    assert list(doc_ids) == [d for d, _ in docs]
    # docs_strategy caps docs at 30 pairs < nnz_pad: nothing may be dropped
    assert n_trunc == 0
    for r, (d, _) in enumerate(docs):
        pairs = sorted(back[d])
        got = [(int(i), int(v)) for i, v in zip(ids[r], vals[r]) if i >= 0]
        assert got == pairs[:32]
        want_norm = np.sqrt(sum(float(v) ** 2 for _, v in pairs[:32]))
        np.testing.assert_allclose(norms[r], want_norm, rtol=1e-5, atol=1e-6)


def test_bandwidth_saving_claim():
    """Paper: ~50% saving vs the one-tuple-per-line UCI format for typical
    documents (60 words/doc)."""
    rng = np.random.default_rng(0)
    docs = []
    for d in range(1000):
        words = rng.choice(141_000, 60, replace=False)
        docs.append((d, [(int(w), int(rng.integers(1, 50))) for w in words]))
    saving = 1 - sf.stream_bytes(docs) / sf.uci_bytes(docs)
    assert 0.45 <= saving <= 0.55, f"saving {saving:.3f}"


def test_truncation_is_explicit():
    docs = [(0, [(w, 1) for w in range(40)]), (1, [(w, 1) for w in range(10)])]
    _, ids, vals, _, n_trunc = sf.decode_to_ell(sf.encode(docs), nnz_pad=16)
    assert (ids[0] >= 0).sum() == 16
    assert n_trunc == 40 - 16          # dropped pairs are reported, not silent
    assert (ids[1] >= 0).sum() == 10   # shorter docs unaffected
    # the no-truncation case reports zero
    *_, none_trunc = sf.decode_to_ell(sf.encode(docs), nnz_pad=64)
    assert none_trunc == 0
