"""Fused decode+match+top-k kernel (DESIGN.md §12): stream tiling
parity with the staged decoder, and bit-identity of the
``pallas_fused`` backend with the ``jnp`` reference on every serving
surface — engine, streaming slabs, storage session (cold and warm),
ingest snapshot, and the stream ingest path itself."""
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.configs.paper_search import SearchConfig
from repro.core import stream_format as sf
from repro.core import topk as topk_lib
from repro.core.corpus import Corpus
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.kernels import fused, ops
from repro.storage import FlashSearchSession, FlashStore

VOCAB = 512


def _cfg(**kw):
    base = dict(name="fused-test", vocab_size=VOCAB, avg_nnz_per_doc=8,
                nnz_pad=16, top_k=4, block_docs=16, block_query=32)
    base.update(kw)
    return SearchConfig(**base)


def _rand_docs(rng, n_docs, max_nnz=12, max_count=30):
    docs = []
    for d in range(n_docs):
        nw = int(rng.integers(0, max_nnz))
        ws = sorted(rng.choice(VOCAB, nw, replace=False).tolist())
        docs.append((d, [(int(w), int(rng.integers(1, max_count)))
                         for w in ws]))
    return docs


def _corpus_from_docs(docs, nnz_pad):
    from repro.core.corpus import from_stream
    return from_stream(sf.encode(docs), nnz_pad)


def _rand_queries(rng, docs, L, qn=6, empty_rows=True):
    qi = np.full((L, qn), -1, np.int32)
    qv = np.zeros((L, qn), np.float32)
    for l in range(L):
        if empty_rows and rng.random() < 0.25:
            continue
        src = docs[int(rng.integers(len(docs)))][1][:qn]
        for j, (w, c) in enumerate(src):
            qi[l, j] = w
            qv[l, j] = c
    return qi, qv


# ---------------------------------------------------------------------------
# tile_stream: host boundary pass vs the staged decoder
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_tile_stream_truncation_parity_with_decode_to_ell(seed):
    """The fused tiler must apply decode_to_ell's exact truncation rule
    (pairs beyond nnz_pad dropped, same count reported) or warm/cold
    stats diverge between the backends."""
    rng = np.random.default_rng(seed)
    docs = _rand_docs(rng, int(rng.integers(1, 40)), max_nnz=14)
    stream = sf.encode(docs)
    nnz_pad = int(rng.integers(1, 12))
    bd = int(2 ** rng.integers(2, 6))
    tiles, n_docs, n_trunc = fused.tile_stream(stream, block_docs=bd,
                                               nnz_pad=nnz_pad)
    doc_ids, ids, vals, _, n_trunc_ref = sf.decode_to_ell(stream, nnz_pad)
    assert n_docs == doc_ids.size
    assert n_trunc == n_trunc_ref
    assert tiles.shape == (-(-n_docs // bd), bd * (1 + nnz_pad))
    # decode every tile back and compare with the staged ELL rows
    got_rows = {}
    for t in range(tiles.shape[0]):
        kept = tiles[t][tiles[t] != fused.PAD_WORD]
        for doc_id, pairs in sf.decode(kept):
            got_rows[doc_id] = pairs
    for r, doc_id in enumerate(doc_ids):
        want = [(int(w), int(v)) for w, v in zip(ids[r], vals[r]) if w >= 0]
        assert got_rows[int(doc_id)] == want


def test_tile_stream_pad_and_empty():
    tiles, n_docs, n_trunc = fused.tile_stream(
        np.empty(0, np.uint32), block_docs=8, nnz_pad=4, pad_docs_to=20)
    assert (tiles == fused.PAD_WORD).all() and tiles.shape == (3, 40)
    assert n_docs == 0 and n_trunc == 0
    stream = sf.encode([(5, [(1, 2)])])
    with pytest.raises(ValueError, match="pad_docs_to"):
        fused.tile_stream(stream, block_docs=8, nnz_pad=4, pad_docs_to=0)


def test_tile_stream_rejects_pad_aliasing_doc_id():
    """doc_id 2^31-1 encodes to the word 0xFFFFFFFF — the fused pad
    sentinel. The staged decoder handles it; the tiler must refuse
    loudly instead of silently dropping the document."""
    stream = sf.encode([(sf.MAX_DOC_ID, [(1, 2)])])
    with pytest.raises(ValueError, match="alias"):
        fused.tile_stream(stream, block_docs=8, nnz_pad=4)


def test_corpus_to_stream_round_trip_and_validation():
    rng = np.random.default_rng(7)
    docs = _rand_docs(rng, 20)
    corpus = _corpus_from_docs(docs, 16).pad_docs_to(24)
    stream = fused.corpus_to_stream(corpus)
    decoded = sf.decode(stream)
    assert len(decoded) == 20          # pad rows skipped
    for (doc_id, pairs), (want_id, want_pairs) in zip(decoded, docs):
        assert doc_id == want_id and pairs == want_pairs
    bad = Corpus(np.array([0]), np.array([[3]], np.int32),
                 np.array([[1.5]], np.float32), np.array([1.5], np.float32))
    with pytest.raises(ValueError, match="integral"):
        fused.corpus_to_stream(bad)


# ---------------------------------------------------------------------------
# bit-identity: engine / streaming / storage / ingest surfaces
# ---------------------------------------------------------------------------
def _assert_same(a, b, label=""):
    np.testing.assert_array_equal(a.doc_ids, b.doc_ids, err_msg=label)
    np.testing.assert_array_equal(a.scores, b.scores, err_msg=label)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_engine_fused_bit_identical_to_jnp(seed):
    rng = np.random.default_rng(seed)
    cfg = _cfg()
    docs = _rand_docs(rng, int(rng.integers(1, 80)))
    corpus = _corpus_from_docs(docs, cfg.nnz_pad)
    ctx = single_device_ctx()
    qi, qv = _rand_queries(rng, docs, L=int(rng.integers(1, 5)))
    ref = PatternSearchEngine(corpus, cfg, ctx, backend="jnp").search(qi, qv)
    got = PatternSearchEngine(corpus, cfg, ctx,
                              backend="pallas_fused").search(qi, qv)
    _assert_same(ref, got, "engine")


def test_engine_fused_streaming_slabs_match_jnp():
    rng = np.random.default_rng(11)
    cfg = _cfg()
    docs = _rand_docs(rng, 60)
    corpus = _corpus_from_docs(docs, cfg.nnz_pad)
    slabs = [corpus.slice_rows(i, i + 20) for i in range(0, 60, 20)]
    ctx = single_device_ctx()
    qi, qv = _rand_queries(rng, docs, L=2, empty_rows=False)
    engines = {b: PatternSearchEngine(None, cfg, ctx, backend=b)
               for b in ("jnp", "pallas_fused")}
    ref = engines["jnp"].search_streaming(qi, qv, iter(slabs))
    got = engines["pallas_fused"].search_streaming(qi, qv, iter(slabs))
    _assert_same(ref, got, "streaming")
    # and the no-slab path returns the (-1, -inf) sentinel
    empty = engines["pallas_fused"].search_streaming(qi, qv, iter([]))
    assert (empty.doc_ids == -1).all()


def test_engine_fused_rejects_multi_device_mesh():
    from repro.distributed.meshctx import MeshCtx
    import jax
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")
    cfg = _cfg()
    mesh = jax.make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    ctx = MeshCtx(mesh=mesh, dp_axes=("data",), fsdp_axis="data",
                  tp_axis="model")
    with pytest.raises(ValueError, match="single-device"):
        PatternSearchEngine(None, cfg, ctx, "pallas_fused")


def test_session_fused_cold_warm_ingest_match_jnp(tmp_path):
    rng = np.random.default_rng(13)
    cfg = _cfg()
    docs = _rand_docs(rng, 150)
    qi, qv = _rand_queries(rng, docs, L=3)
    runs = {}
    for b in ("jnp", "pallas_fused"):
        store = FlashStore.create(str(tmp_path / b), vocab_size=VOCAB,
                                  docs_per_segment=40)
        store.append_docs(docs)
        sess = FlashSearchSession(store, cfg, backend=b)
        cold = sess.search(qi, qv)
        st_cold = sess.last_stats
        warm = sess.search(qi, qv)
        st_warm = sess.last_stats
        assert st_warm.cache_hits == st_warm.segments_scored > 0
        # warm stats replay the cold decode exactly (n_docs + truncation
        # ride the cache entry, for the fused tiler too)
        assert st_warm.docs_scored == st_cold.docs_scored
        assert st_warm.pairs_truncated == st_cold.pairs_truncated
        _assert_same(cold, warm, f"{b} warm")
        sess.enable_ingest()
        sess.append(9000, [(5, 3), (17, 2), (100, 1)])
        live = sess.search(qi, qv)
        assert sess.last_stats.memtable_docs == 1
        runs[b] = (cold, live, st_cold.docs_scored, st_cold.pairs_truncated)
        sess.close()
    _assert_same(runs["jnp"][0], runs["pallas_fused"][0], "cold")
    _assert_same(runs["jnp"][1], runs["pallas_fused"][1], "ingest snapshot")
    assert runs["jnp"][2:] == runs["pallas_fused"][2:]


def test_fused_cache_entries_cannot_alias_ell_entries(tmp_path):
    """One shared SlabCache serving an ELL session and a fused session
    over the same store must key their slabs apart — a PackedSlab
    satisfying an ELL lookup would crash (or worse) at score time."""
    from repro.storage.slabcache import SlabCache
    rng = np.random.default_rng(17)
    cfg = _cfg()
    docs = _rand_docs(rng, 80)
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=VOCAB,
                              docs_per_segment=40)
    store.append_docs(docs)
    shared = SlabCache()
    qi, qv = _rand_queries(rng, docs, L=1, empty_rows=False)
    s_ell = FlashSearchSession(store, cfg, backend="jnp", slab_cache=shared)
    s_fus = FlashSearchSession(store, cfg, backend="pallas_fused",
                               slab_cache=shared)
    r1 = s_ell.search(qi, qv)
    assert s_ell.last_stats.cache_hits == 0
    r2 = s_fus.search(qi, qv)           # same store, different layout:
    assert s_fus.last_stats.cache_hits == 0   # all misses, no aliasing
    _assert_same(r1, r2, "shared cache")
    fmts = {k[-1] for k in shared.keys()}
    assert fmts == {"ell", s_fus.engine.slab_fmt}
    s_fus.close()
    s_ell.close()


def test_put_stream_slab_counts_match_staged_decode():
    rng = np.random.default_rng(19)
    cfg = _cfg(nnz_pad=4)              # force truncation
    docs = _rand_docs(rng, 30, max_nnz=10)
    stream = sf.encode(docs)
    eng = PatternSearchEngine(None, cfg, single_device_ctx(),
                              backend="pallas_fused")
    slab, n_docs, n_trunc = eng.put_stream_slab(stream, pad_docs_to=32)
    _, _, _, _, want_trunc = sf.decode_to_ell(stream, cfg.nnz_pad)
    assert (n_docs, n_trunc) == (30, want_trunc)
    assert slab.tiles.shape[0] == 2    # ceil(32 / block_docs=16)
    ell_eng = PatternSearchEngine(None, cfg, single_device_ctx())
    with pytest.raises(ValueError, match="fused"):
        ell_eng.put_stream_slab(stream)


def test_fused_compile_cache_bound():
    """Varying L within one bucket family reuses programs: the fused
    path keeps the serving bound of <= log2(max_batch)+1 traces."""
    rng = np.random.default_rng(23)
    cfg = _cfg()
    docs = _rand_docs(rng, 40)
    corpus = _corpus_from_docs(docs, cfg.nnz_pad)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                              backend="pallas_fused")
    max_batch = 8
    for L in range(1, max_batch + 1):
        qi, qv = _rand_queries(rng, docs, L=L, empty_rows=False)
        eng.search(qi, qv)
    import math
    assert eng.compile_stats["n_traces"] <= math.log2(max_batch) + 1


def test_fused_partial_topk_fold_matches_flat_topk():
    """k > block_docs: per-tile candidate lists are min(k, bd) wide, so
    no mid-stream pad entry can outrank a later tile's real document —
    the fold must equal a flat top-k even when most scores are -inf."""
    cfg = _cfg(top_k=16, block_docs=8)
    # 20 docs, most empty (score -inf vs any query), ids still real
    docs = [(d, [(d % 7, 1)] if d % 3 == 0 else []) for d in range(20)]
    corpus = _corpus_from_docs(docs, cfg.nnz_pad)
    qi = np.array([[3, -1]], np.int32)
    qv = np.array([[2.0, 0.0]], np.float32)
    ctx = single_device_ctx()
    ref = PatternSearchEngine(corpus, cfg, ctx, backend="jnp").search(qi, qv)
    got = PatternSearchEngine(corpus, cfg, ctx,
                              backend="pallas_fused").search(qi, qv)
    _assert_same(ref, got, "k>bd fold")


def test_fold_topk_pads_and_orders():
    vals = jnp.asarray([[1.0, 3.0, 2.0]])
    ids = jnp.asarray([[10, 30, 20]])
    v, i = topk_lib.fold_topk(vals, ids, 5)
    np.testing.assert_array_equal(np.asarray(i[0]), [30, 20, 10, -1, -1])
    assert np.asarray(v)[0, 3] == -np.inf


# ---------------------------------------------------------------------------
# remaining differential surfaces: cluster scatter/gather and the
# coalesced-submit service path (ISSUE 7 acceptance)
# ---------------------------------------------------------------------------
def test_cluster_fused_matches_jnp(tmp_path):
    from repro.cluster import FlashClusterSession, build_sharded_store
    rng = np.random.default_rng(23)
    cfg = _cfg()
    docs = _rand_docs(rng, 120)
    qi, qv = _rand_queries(rng, docs, L=3)
    runs = {}
    for b in ("jnp", "pallas_fused"):
        cl = build_sharded_store(str(tmp_path / b), docs, n_shards=3,
                                 replicas=1, policy="hash",
                                 vocab_size=VOCAB, docs_per_segment=32)
        with FlashClusterSession(cl, cfg, backend=b) as sess:
            runs[b] = sess.search(qi, qv)
    _assert_same(runs["jnp"], runs["pallas_fused"], "cluster")


def test_service_fused_coalesced_submit_matches_jnp(tmp_path):
    """Coalesced ``submit`` rows through a fused-backend session must be
    bit-identical to serial jnp searches — including a client that
    legitimately submits a zero-term query (all pad ids), which must
    resolve to real doc ids at zero score rather than a shape error."""
    from repro.serve import SearchService
    rng = np.random.default_rng(29)
    cfg = _cfg()
    docs = _rand_docs(rng, 90)
    qi, qv = _rand_queries(rng, docs, L=4, empty_rows=False)
    qi[2, :] = -1                       # zero-term client
    qv[2, :] = 0.0
    store = FlashStore.create(str(tmp_path / "svc"), vocab_size=VOCAB,
                              docs_per_segment=30)
    store.append_docs(docs)
    ref_sess = FlashSearchSession(store, cfg, backend="jnp")
    ref = ref_sess.search(qi, qv)
    sess = FlashSearchSession(store, cfg, backend="pallas_fused")
    svc = SearchService(sess, max_batch=4, max_delay_ms=1.0)
    futs = [svc.submit(qi[l], qv[l]) for l in range(4)]
    rows = [f.result(timeout=30) for f in futs]
    for l, row in enumerate(rows):
        np.testing.assert_array_equal(row.doc_ids, ref.doc_ids[l],
                                      err_msg=f"submit row {l}")
        np.testing.assert_array_equal(row.scores, ref.scores[l],
                                      err_msg=f"submit row {l}")
    # the zero-term row carries real ids at exactly-zero score
    assert np.all(np.asarray(rows[2].scores) == 0.0)
    assert np.all(np.asarray(rows[2].doc_ids) >= 0)
    svc.close()
    sess.close()
    ref_sess.close()
