"""Straggler requeue semantics (distributed/fault.py)."""
from repro.distributed.fault import SlabScheduler


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_all_slabs_processed_in_order():
    s = SlabScheduler(4, timeout_s=10)
    got = []
    while not s.all_done:
        t = s.next_task(worker=0)
        assert t is not None
        assert s.complete(t.slab_id, t.epoch)
        got.append(t.slab_id)
    assert got == [0, 1, 2, 3]


def test_straggler_requeued_and_stale_result_discarded():
    clk = FakeClock()
    s = SlabScheduler(2, timeout_s=5, now=clk)
    t0 = s.next_task(worker=0)        # worker 0 takes slab 0
    assert t0.slab_id == 0 and t0.epoch == 0
    t1 = s.next_task(worker=1)        # worker 1 takes slab 1
    assert s.complete(t1.slab_id, t1.epoch)
    clk.t = 6.0                       # worker 0 straggles past timeout
    t0b = s.next_task(worker=1)       # requeued to worker 1, epoch bumped
    assert t0b.slab_id == 0 and t0b.epoch == 1
    # the straggler finally reports: stale epoch -> discarded
    assert not s.complete(0, epoch=0)
    assert not s.all_done
    # the requeued run completes: accepted
    assert s.complete(0, epoch=1)
    assert s.all_done


def test_no_double_completion():
    s = SlabScheduler(1)
    t = s.next_task(0)
    assert s.complete(t.slab_id, t.epoch)
    assert not s.complete(t.slab_id, t.epoch)   # idempotent
