"""GraphBLAS ops vs dense numpy references."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.core import graphblas as gb


def _random_graph(n, k, seed, weighted=True):
    """ELL adjacency: row r lists (incoming) neighbors."""
    rng = np.random.default_rng(seed)
    ids = np.full((n, k), -1, np.int32)
    vals = np.zeros((n, k), np.float32)
    dense = np.zeros((n, n), np.float32)
    for r in range(n):
        deg = int(rng.integers(0, min(k, n) + 1))
        nbrs = rng.choice(n, deg, replace=False)
        ids[r, :deg] = nbrs
        w = rng.uniform(0.1, 2.0, deg) if weighted else np.ones(deg)
        vals[r, :deg] = w
        dense[r, nbrs] = w
    return ids, vals, dense


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**20), n=st.integers(3, 30), k=st.integers(1, 6))
def test_spmv_plus_times_matches_dense(seed, n, k):
    ids, vals, dense = _random_graph(n, k, seed)
    x = np.random.default_rng(seed + 1).standard_normal(n).astype(np.float32)
    got = gb.spmv_plus_times(jnp.asarray(ids), jnp.asarray(vals),
                             jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), dense @ x, rtol=1e-5,
                               atol=1e-5)


def test_min_plus_is_sssp_relaxation():
    # path graph 0 -> 1 -> 2 -> 3 (incoming lists)
    n = 4
    ids = np.array([[-1], [0], [1], [2]], np.int32)
    vals = np.array([[0.0], [1.0], [2.0], [3.0]], np.float32)
    d = jnp.full((n,), jnp.inf).at[0].set(0.0)
    for _ in range(n):
        d = gb.spmv_min_plus(jnp.asarray(ids), jnp.asarray(vals), d)
    np.testing.assert_allclose(np.asarray(d), [0.0, 1.0, 3.0, 6.0])


def test_pagerank_sums_to_one_and_ranks_hub():
    n, k = 20, 5
    rng = np.random.default_rng(3)
    # everyone links to vertex 0 (hub); incoming ELL for vertex 0 is full
    ids_in = np.full((n, n), -1, np.int32)
    out_deg = np.zeros(n, np.int64)
    for s in range(1, n):
        ids_in[0, s - 1] = s
        out_deg[s] = 1
    vals_in = (ids_in >= 0).astype(np.float32)
    pr = gb.pagerank(jnp.asarray(ids_in[:, :n]),
                     jnp.asarray(vals_in[:, :n]),
                     jnp.asarray(out_deg), iters=60)
    pr = np.asarray(pr)
    np.testing.assert_allclose(pr.sum(), 1.0, rtol=1e-3)
    assert pr[0] == pr.max()


def test_bfs_levels_path_graph():
    n = 6
    # reversed adjacency: row v lists u with edge u->v
    ids = np.full((n, 1), -1, np.int32)
    for v in range(1, n):
        ids[v, 0] = v - 1
    d = gb.bfs_levels(jnp.asarray(ids), src=0, max_iters=n)
    np.testing.assert_allclose(np.asarray(d), np.arange(n, dtype=np.float32))
