"""Admission control (DESIGN.md §7.3): token-bucket quotas, the bounded
pending queue, exactly-once slot release, and the SearchService wiring —
overload is shed at the door with a typed error, never absorbed as a
hang."""
import threading
import time

import numpy as np
import pytest

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.obs import MetricsRegistry
from repro.serve import (AdmissionController, OverloadError, Query,
                         QueryOptions, SearchService, TokenBucket)


class _FakeClock:
    """Injectable monotonic clock: quota refill and the rolling-window
    instruments age off the same timebase, advanced by hand."""

    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# TokenBucket
# ---------------------------------------------------------------------------
def test_token_bucket_burst_then_refill():
    b = TokenBucket(rate=2.0, burst=3.0)
    assert [b.try_take(0.0) for _ in range(3)] == [True, True, True]
    assert not b.try_take(0.0)              # burst drained
    assert b.try_take(0.5)                  # 0.5s * 2/s = 1 token back
    assert not b.try_take(0.5)
    # refill caps at burst: a long idle gap doesn't bank unlimited tokens
    assert [b.try_take(100.0) for _ in range(4)] == [True, True, True, False]


def test_token_bucket_validates():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=0.5)


# ---------------------------------------------------------------------------
# AdmissionController
# ---------------------------------------------------------------------------
def test_admission_queue_full_sheds_typed():
    adm = AdmissionController(max_pending=2)
    r1, r2 = adm.admit(), adm.admit()
    with pytest.raises(OverloadError) as ei:
        adm.admit()
    assert ei.value.reason == "queue_full"
    assert ei.value.depth == 2 and ei.value.limit == 2
    r1()
    adm.admit()                             # slot came back
    assert adm.shed_counts()["queue_full"] == 1
    r2()


def test_admission_quota_refills_on_injected_clock():
    clk = _FakeClock()
    adm = AdmissionController(tenant_qps=1.0, tenant_burst=2.0, clock=clk)
    adm.admit("a")()
    adm.admit("a")()
    with pytest.raises(OverloadError) as ei:
        adm.admit("a")
    assert ei.value.reason == "quota" and ei.value.tenant == "a"
    # a different tenant has its own bucket
    adm.admit("b")()
    clk.advance(1.0)                        # 1s at 1 qps = 1 token
    adm.admit("a")()
    with pytest.raises(OverloadError):
        adm.admit("a")
    assert adm.shed_counts()["quota"] == 2


def test_admission_explicit_quota_overrides_default():
    clk = _FakeClock()
    adm = AdmissionController(tenant_qps=1.0,
                              quotas={"vip": (100.0, 10.0)}, clock=clk)
    for _ in range(10):
        adm.admit("vip")()
    adm.admit("other")()
    with pytest.raises(OverloadError):
        adm.admit("other")


def test_admission_release_is_exactly_once():
    adm = AdmissionController(max_pending=4)
    rel = adm.admit()
    rel()
    rel()                                   # double release must not
    rel()                                   # underflow the depth
    assert adm.depth == 0
    adm.admit()
    assert adm.depth == 1


def test_admission_all_none_admits_everything():
    adm = AdmissionController()
    rels = [adm.admit(f"t{i}") for i in range(64)]
    assert adm.depth == 64
    for r in rels:
        r()
    assert adm.shed_counts() == {"queue_full": 0, "quota": 0}


def test_admission_feeds_registry_counters():
    reg = MetricsRegistry()
    adm = AdmissionController(max_pending=1, registry=reg)
    rel = adm.admit()
    for _ in range(3):
        with pytest.raises(OverloadError):
            adm.admit()
    assert reg.counter("serve_shed_total", reason="queue_full").value == 3
    assert reg.counter("serve_admitted_total").value == 1
    rel()


# ---------------------------------------------------------------------------
# SearchService wiring: shed at submit, slot back on completion
# ---------------------------------------------------------------------------
def _tiny_engine():
    cfg = SearchConfig(name="adm", vocab_size=500, avg_nnz_per_doc=8,
                       nnz_pad=16, top_k=3)
    corpus = corpus_lib.synthesize(60, cfg.vocab_size, 8, cfg.nnz_pad, seed=1)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(), backend="jnp")
    qi, qv = corpus_lib.make_query(corpus, 0, 8)
    return eng, qi, qv


class _GatedSearcher:
    """Blocks every batch on an event so the pending queue backs up
    deterministically."""

    def __init__(self, inner):
        self._inner = inner
        self.gate = threading.Event()

    def search(self, qi, qv):
        self.gate.wait(timeout=10)
        return self._inner._search_arrays(qi, qv)


def test_admission_service_sheds_then_recovers():
    eng, qi, qv = _tiny_engine()
    gated = _GatedSearcher(eng)
    svc = SearchService(gated, max_batch=1, max_delay_ms=0.0, max_pending=2)
    try:
        futs = [svc.submit(Query(qi, qv)) for _ in range(2)]
        with pytest.raises(OverloadError):
            svc.submit(Query(qi, qv))
        gated.gate.set()                    # serve the backlog
        rows = [f.result(timeout=10) for f in futs]
        # completion fired the done-callback releases: slots are back
        deadline = time.monotonic() + 5
        while svc.admission.depth and time.monotonic() < deadline:
            time.sleep(0.005)
        assert svc.admission.depth == 0
        f = svc.submit(Query(qi, qv), options=QueryOptions(tenant="late"))
        resp = f.result(timeout=10)
        np.testing.assert_array_equal(resp.doc_ids, rows[0].doc_ids)
        assert svc.shed_counts()["queue_full"] == 1
    finally:
        gated.gate.set()
        svc.close()


def test_admission_quota_sheds_per_tenant_via_service():
    eng, qi, qv = _tiny_engine()
    svc = SearchService(eng, max_batch=4, max_delay_ms=0.5,
                        tenant_qps=1.0, tenant_burst=1.0)
    try:
        ok = svc.submit(Query(qi, qv), options=QueryOptions(tenant="hot"))
        with pytest.raises(OverloadError) as ei:
            svc.submit(Query(qi, qv), options=QueryOptions(tenant="hot"))
        assert ei.value.reason == "quota" and ei.value.tenant == "hot"
        # the hot tenant can't starve a cold one
        other = svc.submit(Query(qi, qv), options=QueryOptions(tenant="cold"))
        ok.result(timeout=10)
        other.result(timeout=10)
    finally:
        svc.close()
