"""Concurrency stress suite for the serving layer and the prefetcher.

Covers the adversarial paths the happy-path tests never hit: worker
exceptions crossing thread boundaries, early abandonment, degenerate
depth/batch settings, submit storms, and the acceptance criterion that
coalesced results are bit-identical to serial per-query calls under any
interleaving of 16 concurrent clients.
"""
import threading
import time

import numpy as np
import pytest

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine, SearchResult
from repro.distributed.meshctx import single_device_ctx
from repro.serve import MicroBatcher, SearchService
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.prefetch import Prefetcher


# ---------------------------------------------------------------------------
# Prefetcher under stress
# ---------------------------------------------------------------------------
def test_prefetcher_exception_on_first_item():
    def load(i):
        raise OSError("bad sector")

    with pytest.raises(OSError, match="bad sector"):
        next(iter(Prefetcher([1], load, depth=2)))


def test_prefetcher_exception_with_full_queue():
    """The worker dies while the consumer is slow (queue full): the
    error must still surface, at the failing item's position."""
    def load(i):
        if i == 4:
            raise RuntimeError("late failure")
        return i

    pf = Prefetcher(range(8), load, depth=1)
    time.sleep(0.05)                       # let the worker hit backpressure
    got = []
    with pytest.raises(RuntimeError, match="late failure"):
        for v in pf:
            got.append(v)
    assert got == [0, 1, 2, 3]
    pf.close()
    assert not pf._worker.is_alive()


def test_prefetcher_depth1_degenerate_drains_fully():
    with Prefetcher(range(50), lambda i: i, depth=1) as pf:
        assert list(pf) == list(range(50))


def test_prefetcher_abandonment_no_deadlock_no_leaked_segments(tmp_path):
    """Abandon a store-backed stream mid-iteration: close() must return
    promptly (no deadlock on the bounded queue) and every segment handle
    the loader opened must be released again."""
    root = str(tmp_path / "store")
    store = FlashStore.create(root, vocab_size=64, docs_per_segment=4)
    docs = [(i, [(i % 64, 1 + i % 5)]) for i in range(64)]
    store.append_docs(docs)
    names = [e.name for e in store.entries]
    assert len(names) == 16

    def load(name):
        seg = store.segment(name)
        stream = np.array(seg.stream())    # touch the data
        store.release(name)
        return stream

    pf = Prefetcher(names, load, depth=2)
    it = iter(pf)
    next(it)
    next(it)                               # abandon with most items pending
    t0 = time.perf_counter()
    pf.close()
    assert time.perf_counter() - t0 < 5.0
    assert not pf._worker.is_alive()
    assert store._open_segments == {}      # nothing left open
    store.close()


# ---------------------------------------------------------------------------
# MicroBatcher
# ---------------------------------------------------------------------------
class _Req:
    def __init__(self, tag):
        self.tag = tag
        import concurrent.futures
        self.future = concurrent.futures.Future()


def test_batcher_max_batch1_degenerate():
    """max_batch=1: every request is its own batch, nothing waits on the
    delay timer."""
    batches = []

    def run(reqs):
        batches.append([r.tag for r in reqs])
        for r in reqs:
            r.future.set_result(r.tag)

    with MicroBatcher(run, max_batch=1, max_delay_ms=10_000) as mb:
        reqs = [_Req(i) for i in range(5)]
        for r in reqs:
            mb.submit(r)
        assert [r.future.result(timeout=5) for r in reqs] == list(range(5))
    assert batches == [[0], [1], [2], [3], [4]]
    assert mb.stats.flushes["full"] == 5


def test_batcher_timeout_flush_partial_batch():
    done = threading.Event()

    def run(reqs):
        for r in reqs:
            r.future.set_result(len(reqs))
        done.set()

    with MicroBatcher(run, max_batch=64, max_delay_ms=20) as mb:
        r = _Req(0)
        mb.submit(r)
        assert r.future.result(timeout=5) == 1     # flushed alone, by timer
        assert done.wait(timeout=5)
    assert mb.stats.flushes["timeout"] == 1


def test_batcher_submit_storm_every_future_exactly_once():
    """16 threads x 32 submits: every future resolves exactly once, no
    request is dropped or double-batched, order within a client holds."""
    seen = []
    lock = threading.Lock()

    def run(reqs):
        with lock:
            seen.extend(r.tag for r in reqs)
        for r in reqs:
            r.future.set_result(r.tag)

    mb = MicroBatcher(run, max_batch=8, max_delay_ms=1.0)
    results = {}
    rlock = threading.Lock()

    def client(tid):
        for i in range(32):
            r = _Req((tid, i))
            mb.submit(r)
            got = r.future.result(timeout=30)
            with rlock:
                results[(tid, i)] = got

    threads = [threading.Thread(target=client, args=(t,)) for t in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    mb.close()
    assert len(results) == 16 * 32
    assert all(results[k] == k for k in results)
    assert sorted(seen) == sorted(results)          # exactly once, no extras
    assert mb.stats.n_requests == 16 * 32
    assert sum(mb.stats.occupancy) == 16 * 32


def test_batcher_run_exception_fails_only_that_batch():
    calls = []

    def run(reqs):
        calls.append(len(reqs))
        if len(calls) == 1:
            raise ValueError("boom")
        for r in reqs:
            r.future.set_result("ok")

    with MicroBatcher(run, max_batch=2, max_delay_ms=5) as mb:
        bad = [_Req(i) for i in range(2)]
        for r in bad:
            mb.submit(r)
        for r in bad:
            with pytest.raises(ValueError, match="boom"):
                r.future.result(timeout=5)
        good = _Req(9)
        mb.submit(good)
        assert good.future.result(timeout=5) == "ok"   # scheduler survived


def test_batcher_close_drains_then_rejects():
    def run(reqs):
        for r in reqs:
            r.future.set_result(r.tag)

    mb = MicroBatcher(run, max_batch=100, max_delay_ms=60_000)
    reqs = [_Req(i) for i in range(3)]
    for r in reqs:
        mb.submit(r)
    mb.close()                              # must flush the pending 3
    assert [r.future.result(timeout=5) for r in reqs] == [0, 1, 2]
    assert mb.stats.flushes["drain"] == 1
    with pytest.raises(RuntimeError):
        mb.submit(_Req(4))
    mb.close()                              # idempotent


# ---------------------------------------------------------------------------
# SearchService against the real engine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke()
    corpus = corpus_lib.synthesize(256, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=21)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(), backend="jnp")
    return cfg, corpus, eng


def test_service_16_clients_bit_identical_to_serial(engine_setup):
    """The acceptance criterion: any interleaving of 16 concurrent
    clients returns exactly what serial engine.search returns per
    query — same doc_ids, same scores, bit for bit."""
    cfg, corpus, eng = engine_setup
    rng = np.random.default_rng(0)
    idxs = rng.integers(0, corpus.n_docs, 96)
    serial = {}
    for i in set(idxs.tolist()):
        qi, qv = corpus_lib.make_query(corpus, i, 24)
        serial[i] = eng.search(qi[None], qv[None])

    failures = []
    with SearchService(eng, max_batch=8, max_delay_ms=2.0) as svc:
        def client(tid):
            for i in idxs[tid::16]:
                qi, qv = corpus_lib.make_query(corpus, int(i), 24)
                r = svc.submit(qi, qv).result(timeout=60)
                ref = serial[int(i)]
                if not (np.array_equal(r.doc_ids, ref.doc_ids[0])
                        and np.array_equal(r.scores, ref.scores[0])):
                    failures.append(int(i))

        threads = [threading.Thread(target=client, args=(t,))
                   for t in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = svc.stats
    assert failures == []
    assert stats.n_requests == 96


def test_service_compile_cache_bounded(engine_setup):
    """Serving every batch size 1..max_batch compiles at most
    log2(max_batch)+1 programs (the L-bucket cache acceptance bound).
    Queries keep nnz <= block_query so Q capacity tracks the L bucket."""
    cfg, corpus, _ = engine_setup
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(), backend="jnp")
    max_batch = 8
    rng = np.random.default_rng(3)
    for L in list(range(1, max_batch + 1)) * 2:
        qs = [corpus_lib.make_query(corpus, int(rng.integers(corpus.n_docs)),
                                    cfg.block_query)
              for _ in range(L)]
        eng.search(np.stack([q[0] for q in qs]),
                   np.stack([q[1] for q in qs]))
    import math
    bound = int(math.log2(max_batch)) + 1
    assert eng.compile_stats["n_traces"] <= bound, eng.compile_stats
    # and the buckets really are the power-of-two L grid
    ls = sorted({b[0] for b in eng.compile_stats["buckets"]})
    assert ls == [1, 2, 4, 8]


def test_service_searcher_exception_propagates(engine_setup):
    _, _, eng = engine_setup

    class Boom:
        def search(self, qi, qv):
            raise RuntimeError("engine down")

    with SearchService(Boom(), max_batch=4, max_delay_ms=1) as svc:
        fut = svc.submit(np.array([1, 2]), np.array([1.0, 1.0]))
        with pytest.raises(RuntimeError, match="engine down"):
            fut.result(timeout=10)


def test_service_cancelled_future_does_not_poison_batch(engine_setup):
    """A client cancelling its queued Future must not disturb the other
    clients sharing its batch (demux claims futures before scoring)."""
    cfg, corpus, eng = engine_setup
    gate = threading.Event()

    class Gated:
        def search(self, qi, qv):
            gate.wait(timeout=30)
            return eng.search(qi, qv)

    with SearchService(Gated(), max_batch=4, max_delay_ms=1.0) as svc:
        qs = [corpus_lib.make_query(corpus, i, 24) for i in (1, 2, 3)]
        # park the scheduler inside a dummy batch so the real submissions
        # below are guaranteed still queued (PENDING) when we cancel
        dummy = svc.submit(*corpus_lib.make_query(corpus, 0, 24))
        time.sleep(0.2)                    # scheduler is now blocked in Gated
        futs = [svc.submit(qi, qv) for qi, qv in qs]
        assert futs[1].cancel()            # cancel while queued
        gate.set()
        dummy.result(timeout=60)
        for i in (0, 2):
            r = futs[i].result(timeout=60)
            ref = eng.search(qs[i][0][None], qs[i][1][None])
            np.testing.assert_array_equal(r.doc_ids, ref.doc_ids[0])
        assert futs[1].cancelled()


def test_service_rejects_mismatched_query():
    class Never:
        def search(self, qi, qv):
            return SearchResult(np.full((qi.shape[0], 1), -1, np.int64),
                                np.zeros((qi.shape[0], 1), np.float32))

    with SearchService(Never(), max_batch=2, max_delay_ms=1) as svc:
        with pytest.raises(ValueError):
            svc.submit(np.array([1, 2, 3]), np.array([1.0]))


# ---------------------------------------------------------------------------
# FlashSearchSession.submit (storage-backed serving)
# ---------------------------------------------------------------------------
def test_flash_session_submit_matches_blocking_search(tmp_path):
    cfg = smoke()
    corpus = corpus_lib.synthesize(120, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=9)
    root = str(tmp_path / "store")
    store = FlashStore.create(root, vocab_size=cfg.vocab_size,
                              docs_per_segment=40)
    store.append_corpus(corpus)
    with FlashSearchSession(store, cfg) as sess:
        idxs = [3, 77, 119, 40]
        serial = {}
        for i in idxs:
            qi, qv = corpus_lib.make_query(corpus, i, 24)
            serial[i] = sess.search(qi[None], qv[None])
        futs = []
        for i in idxs:                     # concurrent, coalesced
            qi, qv = corpus_lib.make_query(corpus, i, 24)
            futs.append((i, sess.submit(qi, qv)))
        for i, f in futs:
            r = f.result(timeout=120)
            np.testing.assert_array_equal(r.doc_ids, serial[i].doc_ids[0])
            np.testing.assert_array_equal(r.scores, serial[i].scores[0])
        assert sess.service().stats.n_requests == len(idxs)
    # close() tore the service down: submit must now fail, not hang
    with pytest.raises(RuntimeError):
        sess.submit(np.array([1]), np.array([1.0]))
