"""Chaos leg (DESIGN.md §7.3, CI `scheduling` job): inject a slow shard
replica into a 2x2 cluster and prove the scheduling layer keeps the SLO
green — hedging outruns the straggler so the answer is complete
(partial=False) and bit-identical, and partial gather caps the damage
when hedging is off."""
import time

import numpy as np
import pytest

from repro.cluster import FlashClusterSession, build_sharded_store
from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.serve import HedgePolicy, Query, QueryOptions
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs

SLOW_S = 0.5            # injected straggler delay
SLO_MS = 400.0          # the budget a query must stay under


class _Slow:
    """Sleep-wrapped shard-replica session: the injected straggler."""

    def __init__(self, inner, delay_s):
        self._inner = inner
        self._delay = delay_s

    def search(self, *a, **k):
        time.sleep(self._delay)
        return self._inner.search(*a, **k)

    def __getattr__(self, name):
        return getattr(self._inner, name)


@pytest.fixture(scope="module")
def cluster(tmp_path_factory):
    cfg = smoke()
    corpus = corpus_lib.synthesize(160, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=17)
    docs = _corpus_docs(corpus)
    tmp = tmp_path_factory.mktemp("chaos")
    cl = build_sharded_store(str(tmp / "c2x2"), docs, n_shards=2,
                             replicas=2, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=16)
    sess = FlashClusterSession(
        cl, cfg,
        hedge_policy=HedgePolicy(percentile=0.95, min_ms=1.0,
                                 fallback_ms=30.0))
    union = FlashStore.create(str(tmp / "u"), vocab_size=cfg.vocab_size,
                              docs_per_segment=64)
    union.append_docs(docs)
    ref = FlashSearchSession(union, cfg)
    # warm every replica (open + compile) with DIRECT shard-session
    # calls — these bypass the router so they never reach the
    # cluster_shard_ms window — then seed that window with router-level
    # queries that are all-warm. The hedge timer is a percentile of the
    # window, and a cold-compile outlier from a first router query
    # would push the hedge past the deadline budget on a loaded machine
    wi, wv = corpus_lib.make_query(corpus, 0, cfg.max_query_nnz)
    wq = Query(wi[None], wv[None])
    for s in range(2):
        for r in range(2):
            sess.router._session(s, r).search_typed(wq)
    for _ in range(3):
        sess.search_typed(wq)
    yield cfg, corpus, sess, ref
    sess.close()
    ref.close()


def test_chaos_hedging_keeps_slo_green_and_complete(cluster):
    """The headline chaos assertion: with a replica stuck for SLOW_S,
    hedging wins the race — every query completes under the SLO with a
    FULL (partial=False) bit-identical answer."""
    cfg, corpus, sess, ref = cluster
    qs = [corpus_lib.make_query(corpus, i, cfg.max_query_nnz)
          for i in (3, 41, 77)]
    # every replica is already open + warm (module fixture)
    sess.router._sessions[1][0] = _Slow(sess.router._sessions[1][0], SLOW_S)
    try:
        for qi, qv in qs:
            q = Query(qi[None], qv[None])
            expect = ref.search_typed(Query(qi[None], qv[None]))
            t0 = time.monotonic()
            resp = sess.search(q, options=QueryOptions(
                deadline_ms=SLO_MS, allow_partial=True))
            wall_ms = (time.monotonic() - t0) * 1e3
            # SLO green: hedging won, so the answer is complete AND fast
            assert not resp.stats.partial, \
                f"hedge should have beaten the straggler; missing " \
                f"{resp.stats.shards_missing}"
            assert resp.stats.hedged
            assert wall_ms < SLO_MS, f"query took {wall_ms:.0f}ms"
            np.testing.assert_array_equal(resp.doc_ids, expect.doc_ids)
            np.testing.assert_array_equal(resp.scores, expect.scores)
        st = sess.last_stats
        assert st.hedges >= 1 and st.hedge_wins >= 1
        # the slow replica is degraded, not dead: never marked down
        assert not sess.router._down[1][0]
    finally:
        # unwrap so later module-scope users see the healthy replica
        sess.router._sessions[1][0] = sess.router._sessions[1][0]._inner


def test_chaos_partial_gather_caps_damage_without_hedging(cluster):
    """Same straggler with hedging pinned off: the deadline-bound gather
    degrades to a flagged partial answer inside the budget instead of
    stalling for the straggler."""
    cfg, corpus, sess, ref = cluster
    qi, qv = corpus_lib.make_query(corpus, 19, cfg.max_query_nnz)
    q = Query(qi[None], qv[None])
    sess.search_typed(q)
    slow = _Slow(sess.router._sessions[1][0], SLOW_S)
    sess.router._sessions[1][0] = slow
    # replica 1 out of rotation: no fail-over target, no hedge target
    sess.router.mark_down(1, 1)
    try:
        t0 = time.monotonic()
        resp = sess.search(q, options=QueryOptions(
            deadline_ms=80.0, allow_partial=True, hedging=False))
        wall_ms = (time.monotonic() - t0) * 1e3
        assert resp.stats.partial and resp.stats.shards_missing == (1,)
        assert not resp.stats.hedged
        assert wall_ms < SLO_MS, f"partial gather took {wall_ms:.0f}ms"
        # bounded staleness, not garbage: what came back is shard 0's
        # true answer
        shard0 = sess.router._session(0, 0).search_typed(q)
        np.testing.assert_array_equal(resp.doc_ids, shard0.doc_ids)
    finally:
        sess.router._sessions[1][0] = slow._inner
        sess.router.reset_health()
