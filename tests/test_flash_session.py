"""FlashSearchSession end-to-end: store-backed search must match the
in-memory engine exactly, and the vocabulary filter must skip segments
(the ISSUE acceptance criteria)."""
import numpy as np
import pytest

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.storage import FlashSearchSession, FlashStore


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    cfg = smoke()
    corpus = corpus_lib.synthesize(500, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=3)
    root = str(tmp_path_factory.mktemp("flash") / "store")
    store = FlashStore.create(root, vocab_size=cfg.vocab_size,
                              docs_per_segment=100)
    store.append_corpus(corpus)
    assert store.n_segments >= 4            # acceptance: spans >= 4 segments
    sess = FlashSearchSession(store, cfg)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(), backend="jnp")
    return cfg, corpus, store, sess, eng


def _queries(corpus, cfg, idxs):
    qs = [corpus_lib.make_query(corpus, i, cfg.max_query_nnz) for i in idxs]
    return np.stack([q[0] for q in qs]), np.stack([q[1] for q in qs])


def test_flash_search_matches_resident_exactly(setup):
    cfg, corpus, store, sess, eng = setup
    qi, qv = _queries(corpus, cfg, [3, 250, 499])
    r = sess.search(qi, qv)
    ref = eng.search(qi, qv)
    np.testing.assert_array_equal(r.doc_ids, ref.doc_ids)
    np.testing.assert_allclose(r.scores, ref.scores, rtol=1e-5, atol=1e-6)
    assert sess.last_stats.segments_total == store.n_segments
    assert sess.last_stats.docs_scored == corpus.n_docs
    assert sess.last_stats.pairs_truncated == 0


def test_filter_disabled_matches_too(setup):
    cfg, corpus, store, _, eng = setup
    sess = FlashSearchSession(store, cfg, use_filter=False)
    qi, qv = _queries(corpus, cfg, [42])
    np.testing.assert_array_equal(sess.search(qi, qv).doc_ids,
                                  eng.search(qi, qv).doc_ids)
    assert sess.last_stats.segments_skipped == 0


def test_sparse_query_skips_segments(tmp_path):
    """Corpus clustered by vocabulary band: one segment per band. A query
    confined to band 0 must skip every other segment via the (exact
    bitmap) filter and still return the right documents."""
    cfg = smoke()
    n_bands, per_band, band_w = 5, 40, 100
    rng = np.random.default_rng(7)
    docs = []
    for b in range(n_bands):
        for i in range(per_band):
            words = rng.choice(np.arange(b * band_w, (b + 1) * band_w),
                               8, replace=False)
            docs.append((b * per_band + i,
                         sorted((int(w), int(rng.integers(1, 9)))
                                for w in words)))
    store = FlashStore.create(str(tmp_path / "bands"),
                              vocab_size=cfg.vocab_size,
                              docs_per_segment=per_band)
    store.append_docs(docs)
    assert store.n_segments == n_bands
    sess = FlashSearchSession(store, cfg)

    target = docs[5]
    qi = np.full((1, cfg.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, cfg.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(target[1]):
        qi[0, j] = w
        qv[0, j] = c
    r = sess.search(qi, qv)
    assert r.doc_ids[0, 0] == target[0]          # self-search wins
    np.testing.assert_allclose(r.scores[0, 0], 1.0, rtol=1e-5)
    st = sess.last_stats
    assert st.segments_skipped >= 1              # acceptance criterion
    assert st.segments_skipped == n_bands - 1    # bitmap filter is exact
    assert st.segments_scored == 1
    assert st.docs_scored == per_band
    # skipped segments must not cost a full-store scan next time either
    assert 0 < st.skip_rate < 1
    sess.close()


def test_all_segments_skipped_returns_empty(tmp_path):
    cfg = smoke()
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=cfg.vocab_size,
                              docs_per_segment=8)
    store.append_docs([(i, [(3, 1), (7, 2)]) for i in range(8)])
    sess = FlashSearchSession(store, cfg)
    qi = np.full((2, 4), -1, np.int32)
    qv = np.zeros((2, 4), np.float32)
    qi[:, 0] = 200                               # word absent from the store
    qv[:, 0] = 1.0
    r = sess.search(qi, qv)
    assert r.doc_ids.shape == (2, cfg.top_k)
    assert (r.doc_ids == -1).all()
    assert np.isneginf(r.scores).all()
    assert sess.last_stats.segments_skipped == 1
    sess.close()


def test_vocab_mismatch_rejected(tmp_path):
    """A store written with a larger vocab than the engine config would
    scatter word ids out of bounds (silently, under jit) — the session
    must refuse it up front like the resident engine constructor does."""
    cfg = smoke()                                 # vocab_size = 512
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=1024)
    with pytest.raises(ValueError, match="vocab_size"):
        FlashSearchSession(store, cfg)
    store.close()


def test_empty_store_search(tmp_path):
    cfg = smoke()
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=cfg.vocab_size)
    sess = FlashSearchSession(store, cfg)
    qi = np.array([[1, 2, -1, -1]], np.int32)
    qv = np.array([[1.0, 1.0, 0.0, 0.0]], np.float32)
    r = sess.search(qi, qv)
    assert (r.doc_ids == -1).all()
    sess.close()


def test_truncation_reported_in_stats(tmp_path):
    """Documents wider than cfg.nnz_pad surface as pairs_truncated."""
    cfg = smoke()                                 # nnz_pad = 16
    wide = [(0, [(w, 1) for w in range(30)]),
            (1, [(w, 1) for w in range(5)])]
    store = FlashStore.create(str(tmp_path / "s"), vocab_size=cfg.vocab_size)
    store.append_docs(wide)
    sess = FlashSearchSession(store, cfg)
    qi = np.array([[0, 1, 2, -1]], np.int32)
    qv = np.array([[1.0, 1.0, 1.0, 0.0]], np.float32)
    sess.search(qi, qv)
    assert sess.last_stats.pairs_truncated == 30 - cfg.nnz_pad
    sess.close()
