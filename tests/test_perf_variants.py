"""Perf-variant flags must not change model outputs (same math, different
schedule/sharding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.distributed.meshctx import single_device_ctx
from repro.models import model as M
from repro.models import perfcfg


@pytest.fixture(autouse=True)
def _reset():
    perfcfg.reset()
    yield
    perfcfg.reset()


def _logits(cfg, ctx, params, batch):
    return np.asarray(
        jax.jit(lambda p, b: M.apply_train(p, cfg, ctx, b)[0])(params, batch),
        np.float32)


def test_banded_variant_matches_base_gemma3():
    cfg = get_smoke_config("gemma3-4b")
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    base = _logits(cfg, ctx, params, batch)
    perfcfg.set_variant("banded")
    opt = _logits(cfg, ctx, params, batch)
    np.testing.assert_allclose(opt, base, rtol=2e-2, atol=2e-2)


def test_banded_variant_grads_match():
    cfg = get_smoke_config("gemma3-4b")
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    g = jax.jit(jax.grad(lambda p: M.loss_fn(p, cfg, ctx, batch)[0]))
    base = g(params)
    perfcfg.set_variant("banded")
    opt = g(params)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(opt)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-2)


def test_sp_residual_matches_base_moe():
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    base = _logits(cfg, ctx, params, batch)
    perfcfg.set_variant("spresid")
    opt = _logits(cfg, ctx, params, batch)
    np.testing.assert_allclose(opt, base, rtol=2e-2, atol=2e-2)


def test_router_bf16_close_to_fp32():
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    opt = _logits(cfg, ctx, params, batch)          # router_bf16 default ON
    perfcfg.set_variant("paperfaithful")            # fp32-cast router
    base = _logits(cfg, ctx, params, batch)
    # top-k routing can differ on ties; logits must stay close in norm
    denom = np.abs(base).mean() + 1e-6
    assert np.abs(opt - base).mean() / denom < 0.05


def test_a2a_int8_close_to_exact():
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                          cfg.vocab_size)}
    base = _logits(cfg, ctx, params, batch)
    perfcfg.set_variant("a2aint8")
    opt = _logits(cfg, ctx, params, batch)
    denom = np.abs(base).mean() + 1e-6
    assert np.abs(opt - base).mean() / denom < 0.03, \
        np.abs(opt - base).mean() / denom
