"""Deadline/priority-aware batching (DESIGN.md §7.3): EDF ordering,
early deadline flushes, typed expiry before device work, and the
bit-identity guarantee — no deadline pressure means exactly the legacy
FIFO schedule and exactly the legacy results."""
import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.configs.paper_search import SearchConfig
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.serve import (DeadlineExceeded, MicroBatcher, Query, QueryOptions,
                         SearchService)


class _Req:
    def __init__(self, tag, deadline=None, priority=0):
        self.tag = tag
        self.deadline = deadline
        self.priority = priority
        self.future = Future()


def _collecting_batcher(batches, **kw):
    def run(reqs):
        batches.append([r.tag for r in reqs])
        for r in reqs:
            r.future.set_result(r.tag)
    return MicroBatcher(run, **kw)


# ---------------------------------------------------------------------------
# expiry: typed, before any device work
# ---------------------------------------------------------------------------
def test_deadline_expired_at_submit_never_queues():
    batches = []
    with _collecting_batcher(batches, max_batch=4, max_delay_ms=5.0) as mb:
        r = _Req("late", deadline=time.monotonic() - 0.01)
        mb.submit(r)
        with pytest.raises(DeadlineExceeded) as ei:
            r.future.result(timeout=5)
        assert ei.value.where == "submit"
        assert ei.value.late_ms >= 0.0
        assert mb.pending_count == 0
    assert batches == []                    # no batch ever formed
    assert mb.stats.n_expired == 1


def test_deadline_expired_in_queue_drops_before_scoring():
    """A request that ages out behind a long-running batch is dropped at
    flush time (where="queue"), and the batch that does run never sees
    it."""
    gate = threading.Event()
    batches = []

    def run(reqs):
        batches.append([r.tag for r in reqs])
        for r in reqs:
            r.future.set_result(r.tag)
        gate.wait(timeout=10)               # first batch blocks the loop

    with MicroBatcher(run, max_batch=1, max_delay_ms=0.0) as mb:
        plug = _Req("plug")
        mb.submit(plug)
        plug.future.result(timeout=5)       # the loop is now inside run()
        doomed = _Req("doomed", deadline=time.monotonic() + 0.02)
        alive = _Req("alive")
        mb.submit(doomed)
        mb.submit(alive)
        time.sleep(0.06)                    # doomed expires while queued
        gate.set()
        assert alive.future.result(timeout=5) == "alive"
        with pytest.raises(DeadlineExceeded) as ei:
            doomed.future.result(timeout=5)
        assert ei.value.where == "queue"
    assert all("doomed" not in b for b in batches)
    assert mb.stats.n_expired == 1


# ---------------------------------------------------------------------------
# early flush: a deadline shorter than the flush interval still makes it
# ---------------------------------------------------------------------------
def test_deadline_shorter_than_flush_interval_flushes_early():
    batches = []
    with _collecting_batcher(batches, max_batch=64,
                             max_delay_ms=500.0) as mb:
        t0 = time.monotonic()
        r = _Req("tight", deadline=t0 + 0.05)
        mb.submit(r)
        assert r.future.result(timeout=5) == "tight"
        elapsed = time.monotonic() - t0
        # served well inside the 500ms batching window, on the deadline
        assert elapsed < 0.4, f"flushed at {elapsed*1e3:.0f}ms"
    assert mb.stats.flushes["deadline"] >= 1
    assert mb.stats.n_expired == 0


def test_deadline_none_keeps_legacy_timeout_flush():
    batches = []
    with _collecting_batcher(batches, max_batch=64, max_delay_ms=20.0) as mb:
        for i in range(3):
            mb.submit(_Req(i))
        time.sleep(0.2)
    assert batches and batches[0] == [0, 1, 2]   # FIFO, one batch
    assert mb.stats.flushes["deadline"] == 0
    assert mb.stats.flushes["timeout"] >= 1


# ---------------------------------------------------------------------------
# EDF ordering: priority class first, then deadline, then arrival
# ---------------------------------------------------------------------------
def test_deadline_and_priority_order_the_backlog():
    gate = threading.Event()
    batches = []

    def run(reqs):
        batches.append([r.tag for r in reqs])
        for r in reqs:
            r.future.set_result(r.tag)
        if reqs[0].tag == "plug":
            gate.wait(timeout=10)

    with MicroBatcher(run, max_batch=1, max_delay_ms=0.0) as mb:
        plug = _Req("plug")
        mb.submit(plug)
        plug.future.result(timeout=5)
        far = time.monotonic() + 30.0
        near = time.monotonic() + 10.0      # urgent but far from expiring
        mb.submit(_Req("background", priority=5))       # arrives first
        mb.submit(_Req("far", deadline=far))
        mb.submit(_Req("near", deadline=near))
        mb.submit(_Req("fifo"))                         # no deadline
        gate.set()
        mb.close()                          # drain flushes the backlog
    # within priority 0: deadlines first (near, far), then no-deadline
    # FIFO; priority 5 runs last regardless of arrival order
    assert batches[1:] == [["near"], ["far"], ["fifo"], ["background"]]


def test_deadline_flush_accounting_is_atomic_under_stress():
    """The PR-9 race fix: reason counters, occupancy, and
    last_queue_waits_ms are written in the lock'd section that claims
    the batch, so their totals always reconcile."""
    done = []

    def run(reqs):
        done.append(len(reqs))
        for r in reqs:
            r.future.set_result(r.tag)

    mb = MicroBatcher(run, max_batch=4, max_delay_ms=0.2)
    futs = []

    def client(base):
        for i in range(50):
            r = _Req((base, i))
            mb.submit(r)
            futs.append(r.future)

    threads = [threading.Thread(target=client, args=(t,)) for t in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for f in list(futs):
        f.result(timeout=10)
    mb.close()
    st = mb.stats
    assert st.n_requests == 400 == sum(done)
    assert sum(st.flushes.values()) == st.n_batches == len(done)
    assert sum(st.occupancy) == st.n_requests    # window holds them all
    assert len(mb.last_queue_waits_ms) <= 4
    assert mb.pending_count == 0


# ---------------------------------------------------------------------------
# bit-identity: no deadline pressure == the unscheduled path, exactly
# ---------------------------------------------------------------------------
def _engine():
    cfg = SearchConfig(name="dl", vocab_size=600, avg_nnz_per_doc=10,
                       nnz_pad=16, top_k=4)
    corpus = corpus_lib.synthesize(80, cfg.vocab_size, 10, cfg.nnz_pad,
                                   seed=3)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(), backend="jnp")
    return eng, corpus, cfg


def test_deadline_free_options_are_bit_identical_to_legacy():
    eng, corpus, cfg = _engine()
    queries = [corpus_lib.make_query(corpus, i, 10) for i in range(8)]
    serial = [eng.search_typed(Query(qi, qv)) for qi, qv in queries]
    with SearchService(eng, max_batch=4, max_delay_ms=1.0) as svc:
        legacy = [svc.submit(Query(qi, qv)) for qi, qv in queries]
        rows = [f.result(timeout=30) for f in legacy]
        # a generous deadline exerts no pressure: same results, plus stats
        opted = [svc.submit(Query(qi, qv),
                            options=QueryOptions(deadline_ms=60_000.0))
                 for qi, qv in queries]
        resps = [f.result(timeout=30) for f in opted]
    for l in range(8):
        np.testing.assert_array_equal(rows[l].doc_ids,
                                      serial[l].doc_ids[0])
        np.testing.assert_array_equal(resps[l].doc_ids,
                                      serial[l].doc_ids[0])
        np.testing.assert_array_equal(resps[l].scores, serial[l].scores[0])
        assert resps[l].stats.deadline_ms == 60_000.0
        assert resps[l].stats.queue_wait_ms >= 0.0
    assert svc.stats.n_expired == 0


def test_deadline_expiry_through_service_is_typed():
    eng, corpus, _ = _engine()
    qi, qv = corpus_lib.make_query(corpus, 0, 10)
    with SearchService(eng, max_batch=4, max_delay_ms=1.0) as svc:
        f = svc.submit(Query(qi, qv),
                       options=QueryOptions(deadline_ms=-1.0))
        with pytest.raises(DeadlineExceeded) as ei:
            f.result(timeout=10)
        assert ei.value.where == "submit"
        ok = svc.submit(Query(qi, qv))      # the service keeps serving
        assert ok.result(timeout=10).doc_ids.shape == (4,)
