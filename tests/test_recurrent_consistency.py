"""Chunked prefill and step-by-step decode must agree for the recurrent
archs (rwkv6, zamba2): the chunked decay algebra has off-by-one hazards
that only this cross-check catches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.distributed.meshctx import single_device_ctx
from repro.models import model as M


@pytest.mark.parametrize("arch", ["rwkv6-7b", "zamba2-1.2b"])
def test_decode_continues_prefill_exactly(arch):
    """logits(prefill S+1)[last] == logits(decode step after prefill S)."""
    cfg = get_smoke_config(arch)
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab_size)

    # full prefill over S+1 tokens
    full_logits, _, _ = jax.jit(
        lambda p, t: M.apply_prefill(p, cfg, ctx, {"tokens": t}))(
            params, toks)

    # prefill S tokens, then one decode step with token S
    _, _, cache = jax.jit(
        lambda p, t: M.apply_prefill(p, cfg, ctx, {"tokens": t}))(
            params, toks[:, :S])
    if cfg.family == "hybrid":
        # grow the shared-attn KV cache to S+1 before the step
        full = M.init_cache(cfg, B, S + 4)
        cache = jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice(
                dst, src.astype(dst.dtype), (0,) * src.ndim)
            if dst.shape != src.shape else src, full, cache)
    step_logits, _, _ = jax.jit(
        lambda p, t, c: M.apply_decode(p, cfg, ctx, {"tokens": t}, c,
                                       jnp.int32(S)))(
            params, toks[:, S:S + 1], cache)

    # tolerance: the models run bf16; prefill vs decode reduce in different
    # orders (chunked SSD vs step, blockwise vs full-cache attention). The
    # isolated Mamba block agrees to 2e-7 in fp32 (verified); end-to-end
    # bf16 noise is ~1% of logit scale.
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32), rtol=3e-2, atol=5e-2)


@pytest.mark.parametrize("arch", ["rwkv6-7b"])
def test_chunk_size_invariance(arch):
    """The chunked WKV result must not depend on the chunk size."""
    from repro.models import rwkv6
    cfg = get_smoke_config(arch)
    ctx = single_device_ctx()
    params = M.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0,
                              cfg.vocab_size)
    l4, _, _ = jax.jit(lambda p, t: rwkv6.forward(
        p, cfg, ctx, {"tokens": t}, mode="train", chunk=4))(params, toks)
    l12, _, _ = jax.jit(lambda p, t: rwkv6.forward(
        p, cfg, ctx, {"tokens": t}, mode="train", chunk=12))(params, toks)
    # bf16 accumulation order differs with the chunk split; observed worst
    # case is ~2.3e-2 on isolated logits (same noise class as the prefill/
    # decode check above, which allows 3e-2/5e-2)
    np.testing.assert_allclose(np.asarray(l4, np.float32),
                               np.asarray(l12, np.float32), rtol=3e-2,
                               atol=3e-2)
