"""Packed-format (Fig. 8 in-HBM) kernel vs the unpacked kernel + oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.sparse_match_packed import pack, PAD_WORD
from tests.test_kernels import _mk


@pytest.mark.parametrize("case", [
    (16, 8, 16, 1, 256, 8, 8),
    (32, 16, 32, 2, 512, 16, 16),
    (64, 32, 24, 4, 1024, 32, 8),
])
def test_packed_matches_oracle(case):
    D, K, Qn, L, vocab, bd, bq = case
    ids, vals, mi, mv = _mk(D, K, Qn, L, vocab, seed=hash(case) % 2**31)
    packed = pack(ids, vals)
    got = ops.correlate(jnp.asarray(packed), jnp.asarray(vals),
                        jnp.asarray(mi), jnp.asarray(mv),
                        backend="pallas_packed", block_docs=bd,
                        block_query=bq)
    want = ref.sparse_match_ref(jnp.asarray(ids), jnp.asarray(vals),
                                jnp.asarray(mi), jnp.asarray(mv), vocab)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pack_roundtrip_and_sentinel():
    ids = np.array([[5, 100, -1], [0, (1 << 19) - 1, -1]], np.int32)
    vals = np.array([[1, 4095, 99], [7, 2, 0]], np.float32)
    p = pack(ids, vals)
    assert p[0, 2] == PAD_WORD and p[1, 2] == PAD_WORD
    back_ids = (p >> 12).astype(np.int64)
    back_vals = (p & 0xFFF).astype(np.float32)
    m = ids >= 0
    np.testing.assert_array_equal(back_ids[m], ids[m])
    np.testing.assert_array_equal(back_vals[m], np.clip(vals[m], 0, 4095))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**20))
def test_property_packed_equals_unpacked(seed):
    ids, vals, mi, mv = _mk(24, 8, 16, 2, 128, seed=seed)
    a = ops.correlate(jnp.asarray(ids), jnp.asarray(vals), jnp.asarray(mi),
                      jnp.asarray(mv), backend="pallas", block_docs=8,
                      block_query=8)
    b = ops.correlate(jnp.asarray(pack(ids, vals)), jnp.asarray(vals),
                      jnp.asarray(mi), jnp.asarray(mv),
                      backend="pallas_packed", block_docs=8, block_query=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
