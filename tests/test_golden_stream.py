"""Golden-file round-trip for the Fig. 8 segment stream.

The fixtures under tests/golden/ are checked in; these tests assert
*byte-exact* encode and decode against them, so any drift in the wire
format — bit layout, page splitting, footer JSON, filter payload —
breaks loudly instead of silently corrupting every store on disk.

Fixture contents (see docs.json): an empty document, wordID 0 and the
19-bit max, a saturated 12-bit count, a document longer than the ELL
pad (truncation), and the 31-bit max doc id — every corner the format
defines. Regenerate (only for a deliberate, versioned format change) by
re-running the snippet in this file's git history.
"""
import json
import os

import numpy as np

from repro.core import stream_format as sf
from repro.core.corpus import from_stream
from repro.storage import segment as segment_lib

GOLDEN = os.path.join(os.path.dirname(__file__), "golden")
NNZ_PAD = 16


def _docs():
    with open(os.path.join(GOLDEN, "docs.json")) as f:
        return [(d, [tuple(p) for p in pairs]) for d, pairs in json.load(f)]


def _stream_bytes():
    with open(os.path.join(GOLDEN, "stream.bin"), "rb") as f:
        return f.read()


def test_encode_is_byte_exact():
    got = sf.encode(_docs()).astype("<u4").tobytes()
    assert got == _stream_bytes(), "Fig. 8 encode drifted from golden bytes"


def test_decode_is_exact():
    stream = np.frombuffer(_stream_bytes(), dtype="<u4")
    assert sf.decode(stream) == _docs()


def test_decode_to_ell_matches_golden_incl_truncation():
    stream = np.frombuffer(_stream_bytes(), dtype="<u4")
    doc_ids, ids, vals, norms, n_trunc = sf.decode_to_ell(stream, NNZ_PAD)
    want = np.load(os.path.join(GOLDEN, "ell.npz"))
    assert n_trunc == int(want["n_trunc"]) == 24   # the 40-pair doc @ pad 16
    np.testing.assert_array_equal(doc_ids, want["doc_ids"])
    np.testing.assert_array_equal(ids, want["ids"])
    np.testing.assert_array_equal(vals, want["vals"])
    np.testing.assert_array_equal(norms, want["norms"])
    # strict ingest refuses exactly because of those truncated pairs
    import pytest
    with pytest.raises(ValueError, match="truncated"):
        from_stream(stream, NNZ_PAD, strict=True)


def test_segment_write_is_byte_exact(tmp_path):
    """write_segment is fully deterministic: same docs -> same file, to
    the byte — page splits, bloom filter payload, footer JSON and all."""
    out = str(tmp_path / "seg.rsps")
    segment_lib.write_segment(out, _docs(), page_items=16,
                              vocab_size=1 << 19, filter_kind="bloom")
    with open(out, "rb") as f:
        got = f.read()
    with open(os.path.join(GOLDEN, "segment.rsps"), "rb") as f:
        want = f.read()
    assert got == want, "segment writer drifted from golden bytes"


def test_segment_v1_legacy_opens_without_postings():
    """segment_v1.rsps is the pre-postings golden file (PR 1–9 format,
    no posting-index block). It must keep opening forever: the reader
    treats the posting index as optional (``Segment.postings`` is None)
    and the planner falls back to exact scoring for such segments
    (DESIGN.md §15.1)."""
    with segment_lib.Segment(
            os.path.join(GOLDEN, "segment_v1.rsps")) as seg:
        assert "postings" not in seg.footer
        assert seg.postings is None
        assert seg.n_docs == 5
        rebuilt = np.concatenate([seg.page_stream(i)
                                  for i in range(seg.n_pages)])
        np.testing.assert_array_equal(
            rebuilt, np.frombuffer(_stream_bytes(), dtype="<u4"))
        words = np.unique([w for _, ps in _docs() for w, _ in ps])
        assert seg.vocab_filter.contains(words).all()


def test_segment_footer_index_matches_golden():
    with open(os.path.join(GOLDEN, "footer.json")) as f:
        want = json.load(f)
    with segment_lib.Segment(os.path.join(GOLDEN, "segment.rsps")) as seg:
        assert seg.footer == want
        assert seg.n_docs == 5
        assert seg.doc_id_range == (0, (1 << 31) - 1)
        # pages tile the stream exactly and decode independently
        rebuilt = np.concatenate([seg.page_stream(i)
                                  for i in range(seg.n_pages)])
        np.testing.assert_array_equal(
            rebuilt, np.frombuffer(_stream_bytes(), dtype="<u4"))
        per_page = [d for i in range(seg.n_pages)
                    for d in sf.decode(seg.page_stream(i))]
        assert per_page == _docs()
        # the persisted filter still answers membership for every word
        words = np.unique([w for _, ps in _docs() for w, _ in ps])
        assert seg.vocab_filter.contains(words).all()
