"""Rolling-window instruments (DESIGN.md §8.4): lazy ring rotation
under an injectable clock, merged-window percentiles sharing the
lifetime interpolation, registry-attached twins on every existing
handle, the 16-thread observe+rotate hammer, and the Obs.disabled()
zero-clock-read floor in the plan executor."""
import threading

import numpy as np
import pytest

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.obs import Obs, MetricsRegistry
from repro.obs.metrics import percentile_from_state
from repro.obs.window import WindowedCounter, WindowedHistogram
from repro.storage import FlashSearchSession, FlashStore

CFG = smoke()


class FakeClock:
    """Deterministic, thread-safe monotonic clock for rotation tests."""

    def __init__(self, t: float = 0.0):
        self._t = t
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> None:
        with self._lock:
            self._t += dt


# -- rotation mechanics ------------------------------------------------

def test_counter_expires_after_window():
    clk = FakeClock()
    c = WindowedCounter(window_s=10.0, slices=5, clock=clk)
    c.inc(3)
    assert c.total() == 3
    clk.advance(4.0)              # 2 slices later: still inside window
    c.inc(2)
    assert c.total() == 5
    clk.advance(7.0)              # first obs now > window_s old
    assert c.total() == 2
    clk.advance(10.0)             # everything aged out
    assert c.total() == 0
    assert c.rate_per_s() == 0.0


def test_histogram_rotation_is_incremental():
    clk = FakeClock()
    h = WindowedHistogram(window_s=6.0, slices=3, clock=clk)
    for t, v in ((0.0, 1.0), (2.0, 10.0), (4.0, 100.0)):
        while clk() < t:
            clk.advance(2.0)
        h.observe(v)
    assert h.count == 3
    clk.advance(2.0)              # t=6: the t=0 slice expires
    assert h.count == 2
    clk.advance(2.0)              # t=8: the t=2 slice expires
    assert h.count == 1
    st = h.state()
    assert st.lo == st.hi == 100.0
    clk.advance(100.0)            # idle gap >> window: all clear
    assert h.count == 0
    assert h.p99 == 0.0           # empty window: percentile is 0, not NaN


def test_spike_ages_out_of_extremes():
    # per-slice min/max: a latency spike must stop pinning the window
    # max after it rotates out (the reason lifetime hists can't drive
    # admission control)
    clk = FakeClock()
    h = WindowedHistogram(window_s=4.0, slices=4, clock=clk)
    h.observe(5000.0)             # the spike
    clk.advance(1.0)
    for _ in range(20):
        h.observe(1.0)
    assert h.state().hi == 5000.0
    clk.advance(3.5)              # spike slice expired, steady slice live
    assert h.state().hi == 1.0
    assert h.p99 <= 1.0 + 1e-9


def test_window_percentiles_match_lifetime_interpolation():
    # same data inside one live window -> merged-window quantiles equal
    # the lifetime histogram's (shared percentile_from_state)
    from repro.obs.metrics import Histogram
    clk = FakeClock()
    w = WindowedHistogram(window_s=60.0, slices=6, clock=clk)
    life = Histogram()
    rng = np.random.default_rng(3)
    for v in rng.gamma(2.0, 20.0, size=500):
        w.observe(float(v))
        life.observe(float(v))
    for q in (0.5, 0.95, 0.99):
        assert w.percentile(q) == pytest.approx(life.percentile(q))
    assert w.state().counts == life.state().counts


def test_fraction_le_empty_window_is_one():
    clk = FakeClock()
    w = WindowedHistogram(window_s=5.0, slices=5, clock=clk)
    assert w.fraction_le(100.0) == 1.0     # no traffic violates nothing
    w.observe(10.0)
    w.observe(1000.0)
    assert 0.0 < w.fraction_le(100.0) < 1.0
    clk.advance(50.0)
    assert w.fraction_le(100.0) == 1.0


def test_bad_window_params_raise():
    with pytest.raises(ValueError):
        WindowedCounter(window_s=0.0)
    with pytest.raises(ValueError):
        WindowedHistogram(slices=0)


# -- registry integration ----------------------------------------------

def test_registry_attaches_twins_to_every_handle():
    reg = MetricsRegistry(window_s=30.0)
    h = reg.histogram("stage_ms", stage="score")
    c = reg.counter("queries_total", surface="store")
    g = reg.gauge("some_gauge")
    h.observe(5.0)
    c.inc(4)
    g.set(1.0)
    w = reg.windowed("stage_ms", stage="score")
    assert w is not None and w.count == 1 and w.window_s == 30.0
    assert reg.windowed("queries_total", surface="store").total() == 4
    assert reg.windowed("some_gauge") is None          # gauges: no twin
    assert reg.windowed("never_created", x="y") is None  # never creates


def test_registry_windows_can_be_disabled():
    reg = MetricsRegistry(windows=False)
    reg.histogram("stage_ms", stage="score").observe(1.0)
    assert reg.windowed("stage_ms", stage="score") is None


def test_prometheus_window_gauges_render():
    clk = FakeClock()
    reg = MetricsRegistry(window_s=60.0, clock=clk)
    reg.histogram("query_ms", surface="store").observe(12.0)
    reg.counter("queries_total", surface="store").inc()
    text = reg.to_prometheus(include_windows=True)
    assert "# TYPE repro_query_ms_window gauge" in text
    assert ('repro_query_ms_window{stat="p99",surface="store",'
            'window="60s"}') in text
    assert ('repro_queries_total_window{stat="total",surface="store",'
            'window="60s"} 1') in text
    # default rendering is unchanged (file exporters, older tests)
    assert "_window" not in reg.to_prometheus()


# -- concurrency -------------------------------------------------------

def test_hammer_16_threads_no_lost_observations():
    # no rotation (huge window): concurrent observes must all land
    h = WindowedHistogram(window_s=3600.0, slices=6)
    c = WindowedCounter(window_s=3600.0, slices=6)
    n_threads, per_thread = 16, 500

    def work(tid):
        for i in range(per_thread):
            h.observe(float(i % 100))
            c.inc()

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    st = h.state()
    assert st.total == n_threads * per_thread
    assert sum(st.counts) == st.total
    assert c.total() == n_threads * per_thread


def test_hammer_concurrent_observe_and_rotate_equals_serial():
    # the same observe/advance schedule driven concurrently (16 threads
    # per phase, rotation forced between phases) and serially must end
    # in the identical merged state — rotation loses nothing the window
    # still covers and keeps nothing it shouldn't
    schedule = [(0.0, 200), (2.0, 150), (4.0, 250), (9.0, 100)]
    window_s, slices, n_threads = 10.0, 5, 16

    def run_concurrent():
        clk = FakeClock()
        h = WindowedHistogram(window_s=window_s, slices=slices, clock=clk)
        for t_at, n_obs in schedule:
            while clk() < t_at:
                clk.advance(window_s / slices)
            barrier = threading.Barrier(n_threads)

            def work(tid):
                barrier.wait()     # all threads race observe + rotate
                for i in range(n_obs):
                    h.observe(float((tid * n_obs + i) % 50))

            threads = [threading.Thread(target=work, args=(t,))
                       for t in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        return h.state()

    def run_serial():
        clk = FakeClock()
        h = WindowedHistogram(window_s=window_s, slices=slices, clock=clk)
        for t_at, n_obs in schedule:
            while clk() < t_at:
                clk.advance(window_s / slices)
            for tid in range(n_threads):
                for i in range(n_obs):
                    h.observe(float((tid * n_obs + i) % 50))
        return h.state()

    a, b = run_concurrent(), run_serial()
    assert a.counts == b.counts
    # the t=0 phase rotated out (clock parked at t=10, window 10 s with
    # 2 s slices -> live slices cover (2, 10]); the rest survived
    assert a.total == b.total == n_threads * (150 + 250 + 100)
    assert a.lo == b.lo and a.hi == b.hi
    assert percentile_from_state(tuple(range(50)), a, 0.99) == \
        percentile_from_state(tuple(range(50)), b, 0.99)


# -- the Obs.disabled() instrumentation floor --------------------------

class _CountingTime:
    """time-module proxy that counts perf_counter reads."""

    def __init__(self, real_time):
        self._real = real_time
        self.reads = 0

    def perf_counter(self):
        self.reads += 1
        return self._real.perf_counter()

    def __getattr__(self, name):
        return getattr(self._real, name)


def test_disabled_obs_does_zero_clock_reads(tmp_path, monkeypatch):
    import time as real_time

    from repro.storage import plan as plan_mod
    from repro.storage import prefetch as prefetch_mod
    from repro.storage import session as session_mod

    corpus = corpus_lib.synthesize(120, CFG.vocab_size,
                                   CFG.avg_nnz_per_doc, CFG.nnz_pad, seed=5)
    root = str(tmp_path / "store")
    store = FlashStore.create(root, vocab_size=CFG.vocab_size,
                              docs_per_segment=40)
    store.append_corpus(corpus)

    proxy = _CountingTime(real_time)
    for mod in (plan_mod, prefetch_mod, session_mod):
        monkeypatch.setattr(mod, "time", proxy)

    qi, qv = corpus_lib.make_query(corpus, 3, CFG.max_query_nnz)
    off = FlashSearchSession(FlashStore.open(root), CFG, obs=Obs.disabled())
    r_off = off.search(qi[None], qv[None])
    off.search(qi[None], qv[None])
    assert proxy.reads == 0, (
        f"Obs.disabled() path read the clock {proxy.reads} times")
    off.close()

    # sanity: the proxy does count when observability is on, and the
    # results are bit-identical either way (the §8 differential)
    on = FlashSearchSession(FlashStore.open(root), CFG, obs=Obs())
    r_on = on.search(qi[None], qv[None])
    assert proxy.reads > 0
    np.testing.assert_array_equal(r_on.doc_ids, r_off.doc_ids)
    np.testing.assert_array_equal(r_on.scores, r_off.scores)
    on.close()
