"""Metrics registry unit tests (DESIGN.md §8.1): instrument semantics,
quantile estimation, Prometheus rendering, thread safety under a
16-thread hammer, and the zero-slab stats regressions the registry
retrofit fixed (SearchStats.cache_hit_rate, ClusterStats with missing
per-shard stats)."""
import threading

import numpy as np
import pytest

from repro.cluster.router import ClusterStats
from repro.obs import (DEFAULT_MS_BUCKETS, MetricsRegistry, NULL_METRIC,
                       NULL_REGISTRY, Obs)
from repro.obs.metrics import Histogram
from repro.storage.session import SearchStats


# -- instruments -------------------------------------------------------

def test_counter_and_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("hits", surface="store")
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = reg.gauge("resident_bytes")
    g.set(100)
    g.inc(-25)
    assert g.value == 75


def test_registry_returns_same_instrument_for_same_key():
    reg = MetricsRegistry()
    assert reg.counter("x", a="1") is reg.counter("x", a="1")
    assert reg.counter("x", a="1") is not reg.counter("x", a="2")
    with pytest.raises(TypeError):
        reg.histogram("x", a="1")        # same key, different kind


def test_histogram_percentiles_interpolate():
    h = Histogram(buckets=(1.0, 2.0, 4.0, 8.0))
    for v in np.linspace(0.1, 7.9, 200):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 200
    # uniform on (0.1, 7.9): p50 ~ 4, p95 ~ 7.5 — the fixed-bucket
    # estimate must land within one bucket width
    assert abs(s["p50"] - 4.0) < 2.0
    assert abs(s["p95"] - 7.5) < 4.0
    assert s["p50"] <= s["p95"] <= s["p99"]


def test_histogram_single_observation_is_exact():
    h = Histogram(buckets=DEFAULT_MS_BUCKETS)
    h.observe(3.7)
    # min/max tightening: one sample pins every quantile to itself
    assert h.p50 == pytest.approx(3.7)
    assert h.p99 == pytest.approx(3.7)


def test_histogram_overflow_bucket():
    h = Histogram(buckets=(1.0, 10.0))
    h.observe(5000.0)
    h.observe(7000.0)
    assert h.count == 2
    assert h.buckets()[-1] == (float("inf"), 2)
    assert h.p50 >= 5000.0


def test_null_registry_is_inert():
    c = NULL_REGISTRY.counter("whatever", x="y")
    c.inc(10)
    assert c is NULL_METRIC
    NULL_REGISTRY.histogram("h").observe(1.0)
    assert list(NULL_REGISTRY.items()) == []


def test_prometheus_rendering():
    reg = MetricsRegistry()
    reg.counter("queries_total", surface="store").inc(3)
    h = reg.histogram("stage_ms", stage="plan", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(20.0)
    text = reg.to_prometheus(prefix="repro")
    assert "# TYPE repro_queries_total counter" in text
    assert 'repro_queries_total{surface="store"} 3' in text
    assert 'repro_stage_ms_bucket{le="1",stage="plan"} 1' in text
    assert 'repro_stage_ms_bucket{le="+Inf",stage="plan"} 2' in text
    assert "repro_stage_ms_count" in text


# -- concurrency: counters must not drop increments --------------------

def test_sixteen_thread_hammer_matches_serial_totals():
    reg = MetricsRegistry()
    n_threads, per_thread = 16, 2000
    c = reg.counter("hammer_total")
    h = reg.histogram("hammer_ms", buckets=(1.0, 10.0, 100.0))

    def worker(tid):
        for i in range(per_thread):
            c.inc()
            h.observe(float(i % 50))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == n_threads * per_thread
    assert h.count == n_threads * per_thread
    # the bucket counts must also conserve every observation
    assert h.buckets()[-1][1] == n_threads * per_thread


def test_concurrent_registry_lookup_returns_one_instrument():
    reg = MetricsRegistry()
    got = []

    def worker():
        got.append(reg.counter("shared", k="v"))

    threads = [threading.Thread(target=worker) for _ in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert all(c is got[0] for c in got)


# -- stats regressions (satellite: zero-slab / None propagation) -------

def test_cache_hit_rate_zero_slab_query_is_zero():
    """A query that skips every segment touches no slabs: the hit rate
    must read 0.0, not raise ZeroDivisionError."""
    st = SearchStats(segments_total=4, segments_skipped=4)
    assert st.cache_hit_rate == 0.0
    assert st.skip_rate == 1.0


def test_cache_hit_rate_tolerates_none_fields():
    st = SearchStats(segments_total=2, cache_hits=None, cache_misses=None)
    assert st.cache_hit_rate == 0.0
    st2 = SearchStats(segments_total=2, cache_hits=3, cache_misses=None)
    assert st2.cache_hit_rate == 1.0


def test_cluster_stats_tolerates_none_shard_stats():
    """A shard that served from a cache-less replica reports None stats;
    the aggregate must skip it instead of raising."""
    a = SearchStats(segments_total=2, segments_scored=2, docs_scored=10,
                    cache_hits=2, cache_misses=0)
    agg = ClusterStats(per_shard=[a, None])
    assert agg.segments_total == 2
    assert agg.docs_scored == 10
    assert agg.cache_hits == 2
    b = SearchStats(segments_total=1, segments_scored=1, docs_scored=5,
                    cache_hits=None, cache_misses=None)
    agg2 = ClusterStats(per_shard=[a, b])
    assert agg2.docs_scored == 15
    assert agg2.cache_hits == 2


# -- the Obs bundle ----------------------------------------------------

def test_note_query_and_slow_query_log():
    obs = Obs(slow_ms=10.0)
    obs.note_query("store", 3.0, docs=5)
    obs.note_query("store", 50.0, docs=7)
    obs.note_query("cluster", 25.0, shards=2)
    slow = obs.slow_query_log()
    assert [r["wall_ms"] for r in slow] == [50.0, 25.0]
    assert slow[0]["docs"] == 7
    assert obs.slow_query_log(threshold_ms=0.0)[-1]["wall_ms"] == 3.0
    hist = obs.registry.histogram("query_ms", surface="store")
    assert hist.count == 2


def test_publish_search_stats_accumulates_counters():
    obs = Obs()
    st = SearchStats(segments_total=3, segments_scored=2, segments_skipped=1,
                     docs_scored=100, cache_hits=2, cache_misses=0)
    obs.publish_search_stats(st, surface="store")
    obs.publish_search_stats(st, surface="store")
    reg = obs.registry
    assert reg.counter("queries_total", surface="store").value == 2
    assert reg.counter("docs_scored_total", surface="store").value == 200
    assert reg.counter("segments_skipped_total", surface="store").value == 2


def test_disabled_obs_records_nothing():
    obs = Obs.disabled()
    obs.note_query("store", 9999.0)
    obs.publish_search_stats(
        SearchStats(segments_total=1, docs_scored=1), surface="store")
    assert obs.slow_query_log(threshold_ms=0.0) == []
    assert list(obs.registry.items()) == []


def test_registry_to_dict_snapshot():
    obs = Obs()
    obs.registry.counter("c", surface="x").inc(2)
    obs.registry.gauge("g").set(7)
    obs.registry.histogram("h").observe(1.0)
    d = obs.registry.to_dict()
    assert d["c"] == [{"labels": {"surface": "x"}, "value": 2}]
    assert d["g"][0]["value"] == 7.0
    assert d["h"][0]["count"] == 1
    assert d["h"][0]["p50"] == 1.0
