"""Property test (ISSUE acceptance): ANY interleaving of append / seal /
compact / search — with or without a simulated crash + WAL replay in the
middle — yields search results bit-identical to a from-scratch store
built over the same document set (DESIGN.md §5).

Runs under real hypothesis when installed (CI) and under the
``tests/hypothesis_compat`` random-sampling fallback otherwise. No
pytest fixtures inside the ``@given`` test (hypothesis's
function-scoped-fixture health check); temp dirs are managed inline.
"""
import shutil
import tempfile

import numpy as np

from hypothesis_compat import given, settings, strategies as st

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs

CFG = smoke()
# a fixed pool: op sequences index into it, so every drawn example is
# deterministic and shrinkable
_CORPUS = corpus_lib.synthesize(120, CFG.vocab_size, CFG.avg_nnz_per_doc,
                                CFG.nnz_pad, seed=42)
_POOL = _corpus_docs(_CORPUS)

# "append" dominates so sequences actually grow state between the
# structural ops; "crash" closes without sealing and reopens through WAL
# replay; "search" is the differential checkpoint
_OP = st.sampled_from(["append", "append", "append", "append", "append",
                       "append", "seal", "compact", "search", "crash"])
_MAX_CHECKS = 3          # fresh reference stores are the expensive part


def _live_session(root, created):
    store = FlashStore.create(root, vocab_size=CFG.vocab_size,
                              docs_per_segment=8) if not created \
        else FlashStore.open(root)
    sess = FlashSearchSession(store, CFG)
    sess.enable_ingest(seal_docs=6, fold_min_segments=2, auto_compact=False)
    return sess


def _reference_result(tmp, docs, qi, qv, tag):
    store = FlashStore.create(f"{tmp}/ref-{tag}", vocab_size=CFG.vocab_size,
                              docs_per_segment=8)
    if docs:
        store.append_docs(docs)
    with FlashSearchSession(store, CFG) as ref:
        return ref.search(qi, qv)


@settings(max_examples=8, deadline=None)
@given(ops=st.lists(_OP, min_size=4, max_size=28))
def test_any_interleaving_matches_fresh_store(ops):
    tmp = tempfile.mkdtemp(prefix="ingest-prop-")
    sess = None
    try:
        root = f"{tmp}/live"
        sess = _live_session(root, created=False)
        appended = []
        checks = 0
        nxt = iter(_POOL)
        for op in ops + ["search"]:          # always verify the end state
            if op == "append":
                d, p = next(nxt)
                sess.append(d, p)
                appended.append((d, p))
            elif op == "seal":
                sess.flush_ingest()
            elif op == "compact":
                sess.ingest.compact_once()
            elif op == "crash":
                # no seal, no clean shutdown: the WAL tail is the only
                # record of memtable docs; reopen must replay it
                sess.ingest.close(seal=False)
                sess.store.close()
                sess = _live_session(root, created=True)
            elif op == "search" and checks < _MAX_CHECKS:
                checks += 1
                probe = appended[-1] if appended else _POOL[0]
                qi = np.full((1, CFG.max_query_nnz), -1, np.int32)
                qv = np.zeros((1, CFG.max_query_nnz), np.float32)
                for j, (w, c) in enumerate(probe[1][:CFG.max_query_nnz]):
                    qi[0, j] = w
                    qv[0, j] = c
                got = sess.search(qi, qv)
                want = _reference_result(tmp, appended, qi, qv, checks)
                np.testing.assert_array_equal(got.doc_ids, want.doc_ids)
                np.testing.assert_array_equal(got.scores, want.scores)
            if op == "search":
                # conservation invariant, crash or not: durable segments
                # plus the memtable hold exactly the appended set
                assert sess.store.n_docs + len(sess.ingest.memtable) \
                    == len(appended)
    finally:
        if sess is not None:
            sess.close()
        shutil.rmtree(tmp, ignore_errors=True)
