"""Per-segment inverted posting index (DESIGN.md §15.1): build
invariants, byte-exact (de)serialization, accumulator correctness vs a
brute-force reference, and the gather's bit-identity with full-stream
decoding — the property the exact re-rank stage inherits exactness
from."""
import numpy as np
import pytest

from repro.core import stream_format as sf
from repro.storage import segment as segment_lib
from repro.storage.postings import (MAX_SEGMENT_DOCS, PostingIndex,
                                    gather_rows)

VOCAB = 8192
NNZ_PAD = 16


def _docs(n_docs, rng, max_nnz=40, vocab=VOCAB):
    """Doc list with the format's corners mixed in: empty docs, dense
    docs longer than NNZ_PAD (truncation), tiny docs."""
    docs = []
    for i in range(n_docs):
        nw = int(rng.integers(0, max_nnz))
        ws = rng.choice(vocab, nw, replace=False)
        docs.append((i, sorted((int(w), int(rng.integers(1, 60)))
                               for w in ws)))
    return docs


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(5)
    docs = _docs(150, rng)
    stream = sf.encode(docs)
    return docs, stream, PostingIndex.build(stream)


# ---------------------------------------------------------------------------
# build invariants
# ---------------------------------------------------------------------------
def test_postings_build_invariants(built):
    docs, stream, idx = built
    assert idx.n_docs == len(docs)
    # terms sorted unique; CSR offsets monotone, covering all postings
    assert np.all(np.diff(idx.term_ids.astype(np.int64)) > 0)
    assert idx.offsets[0] == 0 and idx.offsets[-1] == idx.n_postings
    assert np.all(np.diff(idx.offsets.astype(np.int64)) >= 0)
    # one posting per (doc, word) pair of the stream
    assert idx.n_postings == sum(len(ps) for _, ps in docs)
    # postings within a term list are doc-ascending (stable build sort)
    for t in range(idx.n_terms):
        d = (idx.postings[idx.offsets[t]:idx.offsets[t + 1]] >> 12)
        assert np.all(np.diff(d.astype(np.int64)) >= 0)


def test_postings_norms_are_full_doc_l2(built):
    docs, _, idx = built
    for off, (_, pairs) in enumerate(docs):
        want = np.sqrt(np.float64(sum(c * c for _, c in pairs)))
        np.testing.assert_allclose(idx.norms[off], np.float32(want),
                                   rtol=1e-6)


def test_postings_doc_starts_directory(built):
    docs, stream, idx = built
    hdr = np.flatnonzero((stream & sf.HEADER_BIT) != 0)
    np.testing.assert_array_equal(
        idx.doc_starts, np.append(hdr, stream.size).astype(np.uint32))


def test_postings_empty_stream():
    idx = PostingIndex.build(np.empty(0, np.uint32))
    assert idx.n_docs == 0 and idx.n_postings == 0
    assert idx.candidates(np.asarray([[3]]), np.asarray([[1.0]]),
                          8).size == 0


def test_postings_doc_offset_capacity():
    # offsets pack into 20 bits; the builder must refuse beyond that
    assert MAX_SEGMENT_DOCS == 1 << 20
    idx = PostingIndex.build(sf.encode([(7, [(3, 2)])]))
    assert idx.n_docs == 1 and idx.n_postings == 1


# ---------------------------------------------------------------------------
# (de)serialization
# ---------------------------------------------------------------------------
def test_postings_roundtrip_is_exact(built):
    _, _, idx = built
    raw = idx.to_bytes()
    assert len(raw) == idx.nbytes
    idx2 = PostingIndex.from_bytes(idx.meta(), raw)
    np.testing.assert_array_equal(idx.term_ids, idx2.term_ids)
    np.testing.assert_array_equal(idx.offsets, idx2.offsets)
    np.testing.assert_array_equal(idx.postings, idx2.postings)
    np.testing.assert_array_equal(idx.norms, idx2.norms)
    np.testing.assert_array_equal(idx.doc_starts, idx2.doc_starts)
    assert idx2.to_bytes() == raw


def test_postings_rejects_unknown_kind(built):
    _, _, idx = built
    with pytest.raises(ValueError, match="unknown postings kind"):
        PostingIndex.from_bytes({"kind": "postings0"}, idx.to_bytes())


def test_segment_persists_postings(tmp_path, built):
    docs, _, idx = built
    path = str(tmp_path / "seg.rsps")
    segment_lib.write_segment(path, docs, page_items=512,
                              vocab_size=VOCAB, filter_kind="bloom")
    with segment_lib.Segment(path) as seg:
        assert seg.footer["postings"]["meta"]["kind"] == "postings1"
        np.testing.assert_array_equal(seg.postings.postings, idx.postings)
        np.testing.assert_array_equal(seg.postings.norms, idx.norms)
        assert seg.postings is seg.postings      # lazy, cached


# ---------------------------------------------------------------------------
# accumulator
# ---------------------------------------------------------------------------
def _brute_scores(docs, q_ids, q_vals):
    """Reference accumulator: sum(q_val * count) / full-doc norm."""
    scores = np.zeros((q_ids.shape[0], len(docs)), np.float32)
    for off, (_, pairs) in enumerate(docs):
        cnt = dict(pairs)
        norm = np.sqrt(np.float64(sum(c * c for _, c in pairs))) or 1e-12
        for r in range(q_ids.shape[0]):
            dot = sum(float(v) * cnt.get(int(w), 0)
                      for w, v in zip(q_ids[r], q_vals[r]) if w >= 0)
            scores[r, off] = dot / norm
    return scores


def test_candidates_match_brute_force_ranking(built):
    docs, _, idx = built
    rng = np.random.default_rng(9)
    q_ids = np.full((3, 8), -1, np.int32)
    q_vals = np.zeros((3, 8), np.float32)
    for r in range(3):
        src = docs[int(rng.integers(len(docs)))][1]
        for j, (w, c) in enumerate(src[:8]):
            q_ids[r, j] = w
            q_vals[r, j] = c
    ref = _brute_scores(docs, q_ids, q_vals)
    for n_cand in (1, 5, 20):
        pool = idx.candidates(q_ids, q_vals, n_cand)
        # sorted ascending doc offsets (tie-break preservation contract)
        assert np.all(np.diff(pool) > 0)
        # the pool covers every row's true top-n_cand by score: no doc
        # outside the pool may out-score a row's n_cand-th best inside
        for r in range(3):
            in_pool = np.sort(ref[r, pool])[::-1]
            kth = in_pool[min(n_cand, in_pool.size) - 1]
            outside = np.delete(ref[r], pool)
            if outside.size:
                assert outside.max() <= kth + 1e-6


def test_candidates_full_pool_is_every_doc(built):
    docs, _, idx = built
    q = np.asarray([[docs[3][1][0][0]]], np.int32)
    v = np.ones((1, 1), np.float32)
    np.testing.assert_array_equal(
        idx.candidates(q, v, len(docs)), np.arange(len(docs)))
    np.testing.assert_array_equal(
        idx.candidates(q, v, 10 * len(docs)), np.arange(len(docs)))


def test_candidates_zero_score_docs_are_eligible(built):
    # a query matching nothing still returns a pool: the exact path
    # ranks 0-score docs above -inf filler, so dropping them would
    # break full-pool bit-identity (DESIGN.md §15.2)
    docs, _, idx = built
    q = np.asarray([[VOCAB - 1]], np.int32)   # likely-unmatched term
    v = np.ones((1, 1), np.float32)
    pool = idx.candidates(q, v, 7)
    assert pool.size == 7


# ---------------------------------------------------------------------------
# gather
# ---------------------------------------------------------------------------
def test_gather_rows_bit_identical_to_full_decode(tmp_path, built):
    docs, stream, _ = built
    path = str(tmp_path / "seg.rsps")
    segment_lib.write_segment(path, docs, page_items=512,
                              vocab_size=VOCAB, filter_kind="bloom")
    full = sf.decode_to_ell(stream, NNZ_PAD)
    rng = np.random.default_rng(2)
    with segment_lib.Segment(path) as seg:
        for size in (1, 17, 64, len(docs)):
            sel = np.sort(rng.choice(len(docs), size,
                                     replace=False)).astype(np.int64)
            ids, ell_i, ell_v, norms, n_tr = gather_rows(seg, sel, NNZ_PAD)
            np.testing.assert_array_equal(ids, full[0][sel])
            np.testing.assert_array_equal(ell_i, full[1][sel])
            np.testing.assert_array_equal(ell_v, full[2][sel])
            np.testing.assert_array_equal(norms, full[3][sel])
            # truncation attributed to selected rows only
            hdr = np.flatnonzero((stream & sf.HEADER_BIT) != 0)
            lens = np.diff(np.append(hdr, stream.size)) - 1
            assert n_tr == int(np.maximum(lens[sel] - NNZ_PAD, 0).sum())


def test_gather_rows_empty_selection(tmp_path, built):
    docs, _, _ = built
    path = str(tmp_path / "seg.rsps")
    segment_lib.write_segment(path, docs, page_items=512,
                              vocab_size=VOCAB, filter_kind="bloom")
    with segment_lib.Segment(path) as seg:
        ids, ell_i, ell_v, norms, n_tr = gather_rows(
            seg, np.empty(0, np.int64), NNZ_PAD)
        assert ids.size == 0 and n_tr == 0
        assert ell_i.shape == (0, NNZ_PAD)
