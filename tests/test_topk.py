"""top-k reduction primitives: local_topk padding semantics, the
ppermute butterfly variant vs the all_gather variant on a real
multi-device mesh, and cross-slab _merge_results dedup/ordering."""
import json
import os
import subprocess
import sys

import jax
import numpy as np

from repro.core import topk as topk_lib
from repro.core.engine import SearchResult, _merge_results


# ---------------------------------------------------------------------------
# local_topk: padding rows must never surface as results
# ---------------------------------------------------------------------------
def test_local_topk_masks_padding_rows():
    scores = np.array([[0.9, 0.1],
                       [0.5, 0.2],
                       [0.99, 0.8]], np.float32)   # row 2 is padding
    doc_ids = np.array([10, 11, -1], np.int32)
    v, i = topk_lib.local_topk(jax.numpy.asarray(scores),
                               jax.numpy.asarray(doc_ids), 3)
    v, i = np.asarray(v), np.asarray(i)
    # padding row outranked everything before the fix; now it is -inf/-1
    np.testing.assert_array_equal(i[0], [10, 11, -1])
    np.testing.assert_allclose(v[0, :2], [0.9, 0.5])
    assert np.isneginf(v[0, 2]) and np.isneginf(v[1, 2])
    np.testing.assert_array_equal(i[1], [11, 10, -1])


def test_local_topk_nonfinite_scores_keep_real_ids():
    """Overflow regression (the isfinite -> row-validity mask fix): a
    *real* document whose score overflowed to +inf (or went NaN through
    inf/inf) must keep its doc id — the old ``isfinite(vals)`` mask
    renamed it to -1, silently reporting "no result" for the best hit.
    Padding rows must still be masked, whatever their scores."""
    scores = np.array([[np.inf, 1.0],
                       [np.nan, 2.0],
                       [0.5, np.inf],
                       [7.0, 7.0]], np.float32)      # row 3 is padding
    doc_ids = np.array([10, 11, 12, -1], np.int32)
    v, i = topk_lib.local_topk(jax.numpy.asarray(scores),
                               jax.numpy.asarray(doc_ids), 3)
    v, i = np.asarray(v), np.asarray(i)
    # XLA top_k total order: NaN > inf > finite; ids follow the scores
    np.testing.assert_array_equal(i[0], [11, 10, 12])
    np.testing.assert_array_equal(i[1], [12, 11, 10])
    assert np.isnan(v[0, 0]) and np.isposinf(v[0, 1])
    assert np.isposinf(v[1, 0])
    # the padding row (which held the highest finite scores) never
    # surfaces, under either column's ordering
    assert -1 not in i


def test_local_topk_k_exceeds_rows():
    scores = np.array([[0.3], [0.7]], np.float32)    # [D=2, L=1]
    doc_ids = np.array([4, 9], np.int32)
    v, i = topk_lib.local_topk(jax.numpy.asarray(scores),
                               jax.numpy.asarray(doc_ids), 5)
    v, i = np.asarray(v), np.asarray(i)
    assert v.shape == (1, 5) and i.shape == (1, 5)
    np.testing.assert_array_equal(i[0], [9, 4, -1, -1, -1])
    assert np.isneginf(v[0, 2:]).all()


# ---------------------------------------------------------------------------
# tree_topk_ppermute == tree_topk on an 8-device CPU mesh (subprocess so
# the XLA device-count flag does not leak into other tests)
# ---------------------------------------------------------------------------
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import topk as topk_lib
from repro.distributed.compat import shard_map

assert len(jax.devices()) == 8
mesh = jax.make_mesh((8,), ("data",))
L, k, per = 3, 4, 16
rng = np.random.default_rng(0)
scores = rng.standard_normal((8 * per, L)).astype(np.float32)
doc_ids = np.arange(8 * per, dtype=np.int32)

def local(scores, doc_ids):
    v, i = topk_lib.local_topk(scores, doc_ids, k)
    vg, ig = topk_lib.tree_topk(v, i, k, "data")
    vp, ip = topk_lib.tree_topk_ppermute(v, i, k, "data", 8)
    return vg, ig, vp, ip

f = shard_map(local, mesh=mesh,
              in_specs=(P("data"), P("data")),
              out_specs=(P(), P(), P(), P()),
              check_vma=False)
vg, ig, vp, ip = f(scores, doc_ids)
# oracle: global top-k over all rows
want_v, want_idx = [], []
for l in range(L):
    order = np.argsort(-scores[:, l], kind="stable")[:k]
    want_idx.append(doc_ids[order]); want_v.append(scores[order, l])
print(json.dumps({
    "gather_v": np.asarray(vg).tolist(), "gather_i": np.asarray(ig).tolist(),
    "pp_v": np.asarray(vp).tolist(), "pp_i": np.asarray(ip).tolist(),
    "want_v": np.stack(want_v).tolist(), "want_i": np.stack(want_idx).tolist(),
}))
"""


def test_tree_topk_ppermute_matches_gather_on_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(res["gather_v"], res["want_v"], rtol=1e-6)
    np.testing.assert_allclose(res["pp_v"], res["want_v"], rtol=1e-6)
    # values fully determine ids here (distinct random scores)
    np.testing.assert_array_equal(res["gather_i"], res["want_i"])
    np.testing.assert_array_equal(res["pp_i"], res["want_i"])


# ---------------------------------------------------------------------------
# cross-slab merge
# ---------------------------------------------------------------------------
def _res(ids, scores):
    return SearchResult(np.asarray(ids, np.int64),
                        np.asarray(scores, np.float32))


def test_merge_results_orders_descending():
    a = _res([[1, 2]], [[0.9, 0.5]])
    b = _res([[3, 4]], [[0.7, 0.2]])
    m = _merge_results(a, b, 3)
    np.testing.assert_array_equal(m.doc_ids, [[1, 3, 2]])
    np.testing.assert_allclose(m.scores, [[0.9, 0.7, 0.5]])


def test_merge_results_dedups_keeping_best():
    a = _res([[7, 2]], [[0.9, 0.5]])
    b = _res([[7, 4]], [[0.8, 0.6]])     # 7 appears in both slabs
    m = _merge_results(a, b, 3)
    np.testing.assert_array_equal(m.doc_ids, [[7, 4, 2]])
    np.testing.assert_allclose(m.scores, [[0.9, 0.6, 0.5]])


def test_merge_results_fillers_never_displace():
    ninf = -np.inf
    a = _res([[5, -1, -1]], [[0.4, ninf, ninf]])
    b = _res([[8, -1, -1]], [[0.6, ninf, ninf]])
    m = _merge_results(a, b, 3)
    np.testing.assert_array_equal(m.doc_ids, [[8, 5, -1]])
    assert np.isneginf(m.scores[0, 2])


def test_merge_results_stable_on_ties():
    # equal scores: a's candidate (earlier slab) must come first
    a = _res([[1]], [[0.5]])
    b = _res([[2]], [[0.5]])
    m = _merge_results(a, b, 2)
    np.testing.assert_array_equal(m.doc_ids, [[1, 2]])
