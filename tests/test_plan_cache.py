"""Query planner + device slab cache (DESIGN.md §4): explicit plans,
cache-first scan order, warm-vs-cold bit-equivalence on every scoring
surface, byte-budget eviction, precise invalidation, and the idempotent
close satellites."""
import dataclasses

import numpy as np
import pytest

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.storage import (FlashSearchSession, FlashStore, Planner,
                           Prefetcher, SlabCache)
from repro.storage.plan import SOURCE_CACHE, SOURCE_DISK
from repro.storage.slabcache import slab_nbytes
from repro.storage.store import _corpus_docs

CFG = smoke()


def _build_store(root, corpus, docs_per_segment=100):
    store = FlashStore.create(str(root), vocab_size=CFG.vocab_size,
                              docs_per_segment=docs_per_segment)
    store.append_corpus(corpus)
    return store


def _queries(corpus, idxs):
    qs = [corpus_lib.make_query(corpus, i, CFG.max_query_nnz) for i in idxs]
    return np.stack([q[0] for q in qs]), np.stack([q[1] for q in qs])


@pytest.fixture(scope="module")
def corpus():
    return corpus_lib.synthesize(400, CFG.vocab_size, CFG.avg_nnz_per_doc,
                                 CFG.nnz_pad, seed=11)


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------
def test_plan_verdicts_and_cache_first_order(tmp_path, corpus):
    store = _build_store(tmp_path / "s", corpus)
    sess = FlashSearchSession(store, CFG)
    qi, qv = _queries(corpus, [7])
    plan = sess._planner.plan(store, qi)
    # cold: every surviving segment is a disk step, none skipped for a
    # real document's own words
    assert plan.segments_total == store.n_segments
    assert plan.n_cached == 0 and plan.n_disk == len(plan.steps)
    assert len(plan.steps) + len(plan.skipped) == plan.segments_total
    sess.search(qi, qv)                      # populate the cache
    plan2 = sess._planner.plan(store, qi)
    assert plan2.n_cached == len(plan2.steps) > 0
    # scan order is cache-first by construction: once any step is a
    # disk step, no later step may be a cache hit
    sess.slab_cache.clear()
    sess.search(qi, qv)
    first = plan2.steps[0].name
    sess.slab_cache.invalidate(store.cache_token, [first])
    plan3 = sess._planner.plan(store, qi)
    sources = [s.source for s in plan3.steps]
    assert sources == sorted(sources)        # "cache" < "disk" lexically
    assert plan3.steps[-1].name == first and sources[-1] == SOURCE_DISK
    assert all(s == SOURCE_CACHE for s in sources[:-1])
    sess.close()


def test_plan_executes_through_every_source(tmp_path, corpus):
    """A mixed cache/disk plan scores bit-identically to the resident
    engine (the planner's ordering permutes the slab stream; the
    cross-slab merge is order-independent for distinct doc ids)."""
    store = _build_store(tmp_path / "s", corpus)
    eng = PatternSearchEngine(corpus, CFG, single_device_ctx())
    sess = FlashSearchSession(store, CFG)
    qi, qv = _queries(corpus, [3, 250])
    cold = sess.search(qi, qv)
    # knock half the entries out so the next plan mixes sources
    names = [k[1] for k in sess.slab_cache.keys()]
    sess.slab_cache.invalidate(store.cache_token, names[::2])
    mixed = sess.search(qi, qv)
    st = sess.last_stats
    assert st.cache_hits > 0 and st.cache_misses > 0
    ref = eng.search(qi, qv)
    for got in (cold, mixed):
        np.testing.assert_array_equal(got.doc_ids, ref.doc_ids)
        np.testing.assert_allclose(got.scores, ref.scores,
                                   rtol=1e-5, atol=1e-6)
    sess.close()


# ---------------------------------------------------------------------------
# warm vs cold bit-equivalence, per scoring surface
# ---------------------------------------------------------------------------
def test_warm_equals_cold_single_store(tmp_path, corpus):
    store = _build_store(tmp_path / "s", corpus)
    sess = FlashSearchSession(store, CFG)
    qi, qv = _queries(corpus, [0, 123, 399])
    cold = sess.search(qi, qv)
    cold_stats = sess.last_stats
    assert cold_stats.cache_hits == 0
    assert cold_stats.cache_misses == cold_stats.segments_scored > 0
    warm = sess.search(qi, qv)
    warm_stats = sess.last_stats
    assert warm_stats.cache_hits == warm_stats.segments_scored
    assert warm_stats.cache_misses == 0
    assert warm_stats.cache_hit_rate == 1.0
    np.testing.assert_array_equal(cold.doc_ids, warm.doc_ids)
    np.testing.assert_array_equal(cold.scores, warm.scores)
    # stats must be value-identical too: docs/truncations recorded in
    # the cache entry, not re-derived
    assert warm_stats.docs_scored == cold_stats.docs_scored
    assert warm_stats.pairs_truncated == cold_stats.pairs_truncated
    sess.close()


def test_warm_equals_cold_ingest_snapshot(tmp_path, corpus):
    """The live surface: base segments + sealed deltas + memtable, warm
    vs cold vs a from-scratch reference store."""
    docs = _corpus_docs(corpus)
    base, extra = docs[:300], docs[300:]
    store = _build_store(tmp_path / "live", corpus.slice_rows(0, 300),
                         docs_per_segment=64)
    sess = FlashSearchSession(store, CFG)
    sess.enable_ingest(seal_docs=40, auto_compact=False)
    for d, p in extra[:60]:
        sess.append(d, p)                    # forces one seal + a tail
    qi, qv = _queries(corpus, [5, 320])
    cold = sess.search(qi, qv)
    warm = sess.search(qi, qv)
    assert sess.last_stats.cache_hits > 0
    assert sess.last_stats.memtable_docs == 60 % 40
    np.testing.assert_array_equal(cold.doc_ids, warm.doc_ids)
    np.testing.assert_array_equal(cold.scores, warm.scores)
    ref_store = _build_store(tmp_path / "ref",
                             corpus.slice_rows(0, 360), docs_per_segment=64)
    with FlashSearchSession(ref_store, CFG) as ref:
        want = ref.search(qi, qv)
    np.testing.assert_array_equal(warm.doc_ids, want.doc_ids)
    np.testing.assert_array_equal(warm.scores, want.scores)
    # a fold must not poison the warm path: compact, then re-verify
    sess.flush_ingest()
    sess.ingest.compact_once()
    after = sess.search(qi, qv)
    np.testing.assert_array_equal(after.doc_ids, want.doc_ids)
    np.testing.assert_array_equal(after.scores, want.scores)
    sess.close()


def test_warm_equals_cold_cluster(tmp_path, corpus):
    from repro.cluster import FlashClusterSession, build_sharded_store
    docs = _corpus_docs(corpus)
    croot = str(tmp_path / "cluster")
    build_sharded_store(croot, docs, n_shards=3, replicas=1,
                        vocab_size=CFG.vocab_size, docs_per_segment=64)
    qi, qv = _queries(corpus, [9, 200, 377])
    with FlashClusterSession(croot, CFG) as cs:
        cold = cs.search(qi, qv)
        assert cs.last_stats.cache_hits == 0
        warm = cs.search(qi, qv)
        agg = cs.last_stats
        # aggregated through the scatter/gather path across all shards
        assert agg.cache_hits == agg.segments_scored > 0
        assert agg.cache_misses == 0 and agg.cache_hit_rate == 1.0
        assert cs.cache_stats.hits >= agg.cache_hits
        np.testing.assert_array_equal(cold.doc_ids, warm.doc_ids)
        np.testing.assert_array_equal(cold.scores, warm.scores)
        # all shard sessions share ONE cache instance + byte budget
        shard_sessions = cs.router._open_sessions()
        assert len(shard_sessions) == 3
        assert all(s.slab_cache is cs.slab_cache for s in shard_sessions)


def test_warm_equals_cold_service_submit(tmp_path, corpus):
    """The micro-batched surface: coalesced submits execute through the
    same planner/cache and warm hits stay bit-identical."""
    store = _build_store(tmp_path / "s", corpus)
    sess = FlashSearchSession(store, CFG)
    qi, qv = corpus_lib.make_query(corpus, 77, CFG.max_query_nnz)
    first = sess.submit(qi, qv).result()
    again = sess.submit(qi, qv).result()
    assert sess.last_stats.cache_hits > 0
    np.testing.assert_array_equal(first.doc_ids, again.doc_ids)
    np.testing.assert_array_equal(first.scores, again.scores)
    assert sess.cache_stats.hits > 0
    sess.close()


# ---------------------------------------------------------------------------
# budget, eviction, invalidation
# ---------------------------------------------------------------------------
def test_eviction_under_tiny_budget(tmp_path, corpus):
    store = _build_store(tmp_path / "s", corpus)
    eng = PatternSearchEngine(corpus, CFG, single_device_ctx())
    # budget fits ~2 slabs: steady state must evict yet stay correct
    probe = FlashSearchSession(store, CFG)
    qi, qv = _queries(corpus, [50])
    probe.search(qi, qv)
    one_slab = max(e.nbytes for e in probe.slab_cache._entries.values())
    probe.close()
    store2 = FlashStore.open(str(tmp_path / "s"))
    sess = FlashSearchSession(store2, CFG,
                              cache_bytes=int(one_slab * 2.5))
    for _ in range(3):
        got = sess.search(qi, qv)
    st = sess.last_stats
    assert sess.slab_cache.stats.evictions > 0
    assert sess.slab_cache.nbytes <= sess.slab_cache.max_bytes
    assert len(sess.slab_cache) <= 2
    ref = eng.search(qi, qv)
    np.testing.assert_array_equal(got.doc_ids, ref.doc_ids)
    np.testing.assert_allclose(got.scores, ref.scores, rtol=1e-5, atol=1e-6)
    # with fewer resident slabs than survivors there are hits AND misses
    assert st.cache_misses > 0
    sess.close()


def test_slab_larger_than_budget_not_admitted(tmp_path, corpus):
    store = _build_store(tmp_path / "s", corpus)
    sess = FlashSearchSession(store, CFG, cache_bytes=64)   # absurd budget
    qi, qv = _queries(corpus, [50])
    r1 = sess.search(qi, qv)
    r2 = sess.search(qi, qv)
    assert len(sess.slab_cache) == 0 and sess.slab_cache.nbytes == 0
    assert sess.last_stats.cache_hits == 0
    np.testing.assert_array_equal(r1.doc_ids, r2.doc_ids)
    sess.close()


def test_cache_disabled(tmp_path, corpus):
    store = _build_store(tmp_path / "s", corpus)
    sess = FlashSearchSession(store, CFG, cache_bytes=0)
    assert sess.slab_cache is None and sess.cache_stats is None
    qi, qv = _queries(corpus, [1])
    sess.search(qi, qv)
    sess.search(qi, qv)
    st = sess.last_stats
    assert st.cache_hits == st.cache_misses == st.cache_evictions == 0
    assert st.cache_hit_rate == 0.0
    sess.close()


def test_compact_invalidates_replaced_names(tmp_path, corpus):
    """FlashStore.compact rewrites every segment: the cache must drop
    exactly the replaced names (generation-precise invalidation), and
    the next search must re-decode the new files, not serve stale slabs."""
    store = _build_store(tmp_path / "s", corpus.slice_rows(0, 130),
                         docs_per_segment=40)   # 4 segments, last underfull
    eng = PatternSearchEngine(corpus.slice_rows(0, 130), CFG,
                              single_device_ctx())
    sess = FlashSearchSession(store, CFG)
    qi, qv = _queries(corpus, [10])
    sess.search(qi, qv)
    assert len(sess.slab_cache) > 0
    gen = store.generation
    store.compact()
    assert store.generation == gen + 1
    assert len(sess.slab_cache) == 0            # all old names replaced
    assert sess.slab_cache.stats.invalidations > 0
    got = sess.search(qi, qv)
    assert sess.last_stats.cache_hits == 0      # nothing stale served
    ref = eng.search(qi, qv)
    np.testing.assert_array_equal(got.doc_ids, ref.doc_ids)
    np.testing.assert_allclose(got.scores, ref.scores, rtol=1e-5, atol=1e-6)
    sess.close()


def test_shared_cache_across_sessions(tmp_path, corpus):
    """'Across queries, sessions, and micro-batches': a second session
    over the same store instance warms up from the first one's work."""
    store = _build_store(tmp_path / "s", corpus)
    shared = SlabCache()
    qi, qv = _queries(corpus, [42])
    s1 = FlashSearchSession(store, CFG, slab_cache=shared)
    r1 = s1.search(qi, qv)
    s2 = FlashSearchSession(store, CFG, slab_cache=shared)
    r2 = s2.search(qi, qv)
    assert s2.last_stats.cache_hits == s2.last_stats.segments_scored > 0
    np.testing.assert_array_equal(r1.doc_ids, r2.doc_ids)
    np.testing.assert_array_equal(r1.scores, r2.scores)
    # sessions share lifetime stats through the one cache object;
    # cache_stats returns a locked *snapshot* (not the live mutating
    # dataclass), so shared state is proven by value, and the snapshot
    # must be detached from subsequent cache activity
    snap = s1.cache_stats
    assert snap == s2.cache_stats
    assert snap is not shared.stats
    shared.stats.hits += 1
    try:
        assert s1.cache_stats.hits == snap.hits + 1  # live counters moved
        assert snap == dataclasses.replace(snap)     # snapshot did not
    finally:
        shared.stats.hits -= 1
    # registrations are refcounted: closing one session must neither
    # stop the store's invalidations for the survivor nor wipe the
    # survivor's warm set
    s1.close()
    assert store._caches
    assert len(shared) > 0
    r3 = s2.search(qi, qv)
    assert s2.last_stats.cache_hits == s2.last_stats.segments_scored > 0
    np.testing.assert_array_equal(r3.doc_ids, r1.doc_ids)
    s2.close()
    assert not store._caches
    assert len(shared) == 0


def test_reopened_store_cannot_alias_cache_entries(tmp_path, corpus):
    """Distinct FlashStore instances get distinct cache tokens, so a
    crash-reopened store (which may reuse segment *names* on disk) can
    never be served another instance's slabs."""
    store1 = _build_store(tmp_path / "s", corpus)
    shared = SlabCache()
    s1 = FlashSearchSession(store1, CFG, slab_cache=shared)
    qi, qv = _queries(corpus, [8])
    s1.search(qi, qv)
    store2 = FlashStore.open(str(tmp_path / "s"))
    assert store2.cache_token != store1.cache_token
    s2 = FlashSearchSession(store2, CFG, slab_cache=shared)
    s2.search(qi, qv)
    assert s2.last_stats.cache_hits == 0        # token mismatch = miss
    s2.close()
    s1.close()


def test_nbytes_accounting_matches_slabs(tmp_path, corpus):
    store = _build_store(tmp_path / "s", corpus)
    sess = FlashSearchSession(store, CFG)
    qi, qv = _queries(corpus, [3])
    sess.search(qi, qv)
    cache = sess.slab_cache
    assert cache.nbytes == sum(e.nbytes for e in cache._entries.values())
    assert all(e.nbytes == slab_nbytes(e.slab)
               for e in cache._entries.values())
    sess.close()
    # session close drops the store's entries from the cache
    assert len(cache) == 0 and cache.nbytes == 0


def test_partial_warm_tiebreak_matches_cold(tmp_path):
    """Two byte-identical documents in different segments score exactly
    equal; the merge breaks ties by fold position. A partially warm
    plan scans the cached segment *first* but must still fold in
    manifest order, so the cold scan's winner keeps winning no matter
    which segments happen to be resident."""
    pairs = [(3, 2), (7, 1)]
    docs = []
    for i in range(30):
        if i in (5, 25):
            docs.append((i, pairs))             # the tied twins
        else:
            docs.append((i, [(100 + i, 1)]))    # filler, filtered out
    store = FlashStore.create(str(tmp_path / "tie"),
                              vocab_size=CFG.vocab_size,
                              docs_per_segment=10)
    store.append_docs(docs)
    sess = FlashSearchSession(store, CFG)
    qi = np.full((1, CFG.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, CFG.max_query_nnz), np.float32)
    for j, (w, c) in enumerate(pairs):
        qi[0, j] = w
        qv[0, j] = c
    cold = sess.search(qi, qv)
    assert sess.last_stats.segments_scored == 2       # filler seg skipped
    assert cold.doc_ids[0, 0] == 5                    # manifest-first wins
    assert cold.scores[0, 0] == cold.scores[0, 1]     # genuinely tied
    # leave only the LATER segment resident: the plan now scans it first
    first_seg = store.entries[0].name
    sess.slab_cache.invalidate(store.cache_token, [first_seg])
    partial = sess.search(qi, qv)
    st = sess.last_stats
    assert st.cache_hits == 1 and st.cache_misses == 1
    np.testing.assert_array_equal(partial.doc_ids, cold.doc_ids)
    np.testing.assert_array_equal(partial.scores, cold.scores)
    sess.close()


def test_admission_gated_on_plan_generation(tmp_path, corpus):
    """A plan outlived by a manifest mutation must not admit its slabs:
    they may be graveyard files the mutation just invalidated, and
    re-admitting would undo the precise invalidation."""
    from repro.storage import plan as plan_lib
    from repro.storage.session import SearchStats

    store = _build_store(tmp_path / "s", corpus)
    sess = FlashSearchSession(store, CFG)
    qi, qv = _queries(corpus, [12])
    plan = sess._planner.plan(store, qi)
    store.bump_generation()                  # a fold/compact commits
    stats = SearchStats(segments_total=plan.segments_total,
                        segments_skipped=len(plan.skipped),
                        segments_scored=len(plan.steps))
    plan_lib.execute_plan(sess.engine, store, plan, qi, qv, stats=stats,
                          cache=sess.slab_cache)
    assert len(sess.slab_cache) == 0         # nothing admitted
    # a fresh plan at the live generation admits again
    got = sess.search(qi, qv)
    assert len(sess.slab_cache) > 0
    ref = PatternSearchEngine(corpus, CFG, single_device_ctx()).search(qi, qv)
    np.testing.assert_array_equal(got.doc_ids, ref.doc_ids)
    sess.close()


def test_snapshot_outlived_by_fold_never_readmits(tmp_path, corpus):
    """The racy interleaving: capture -> fold commits (precise
    invalidation) -> the straggling snapshot plans and scores. Its
    graveyard slabs must not be admitted back into the cache — the
    plan's capture-time generation no longer matches the live one."""
    store = _build_store(tmp_path / "live", corpus.slice_rows(0, 200),
                         docs_per_segment=16)
    sess = FlashSearchSession(store, CFG)
    pipe = sess.enable_ingest(seal_docs=8, fold_min_segments=2,
                              auto_compact=False)
    for d, p in _corpus_docs(corpus)[200:230]:
        sess.append(d, p)
    sess.flush_ingest()
    snap = pipe.capture()
    assert pipe.compact_once() > 0           # fold lands mid-"query"
    assert snap.generation != snap.live_generation
    qi, qv = _queries(corpus, [3, 210])
    got = sess._search_view(snap, snap, qi, qv)
    snap.close()
    assert len(sess.slab_cache) == 0         # stale plan admitted nothing
    fresh = sess.search(qi, qv)              # live plan admits + agrees
    assert len(sess.slab_cache) > 0
    np.testing.assert_array_equal(got.doc_ids, fresh.doc_ids)
    np.testing.assert_array_equal(got.scores, fresh.scores)
    sess.close()


# ---------------------------------------------------------------------------
# idempotent close satellites
# ---------------------------------------------------------------------------
def test_prefetcher_close_idempotent_with_unconsumed_items():
    loaded = []

    def load(i):
        loaded.append(i)
        return i * i

    pf = Prefetcher(range(16), load, depth=2)
    assert next(iter(pf)) == 0               # consume one, abandon rest
    pf.close()
    worker = pf._worker
    assert not worker.is_alive()             # no leaked thread
    pf.close()                               # second close: no-op
    pf.close()
    assert not worker.is_alive()
    assert len(loaded) <= 4                  # backpressure held


def test_session_close_idempotent(tmp_path, corpus):
    store = _build_store(tmp_path / "s", corpus)
    sess = FlashSearchSession(store, CFG)
    sess.enable_ingest(seal_docs=1000, auto_compact=False)
    qi, qv = _queries(corpus, [1])
    sess.search(qi, qv)
    sess.close()
    sess.close()                             # must not double-free
    assert not store._caches                 # registration detached once
    with pytest.raises(RuntimeError):
        sess.service()


def test_snapshot_close_idempotent_no_graveyard_double_drain(tmp_path,
                                                             corpus):
    """Closing one snapshot twice must not decrement the live-snapshot
    count twice — that would drain the graveyard under a *different*
    still-open snapshot and delete files it may score."""
    store = _build_store(tmp_path / "live", corpus.slice_rows(0, 200),
                         docs_per_segment=16)
    sess = FlashSearchSession(store, CFG)
    pipe = sess.enable_ingest(seal_docs=8, fold_min_segments=2,
                              auto_compact=False)
    for d, p in _corpus_docs(corpus)[200:230]:
        sess.append(d, p)
    sess.flush_ingest()
    snap_a = pipe.capture()
    snap_b = pipe.capture()
    snap_a.close()
    snap_a.close()                           # idempotent: count stays 1
    assert pipe._live_snapshots == 1
    folded = pipe.compact_once()             # parks replaced files
    assert folded > 0
    assert pipe._graveyard                   # deferred while b lives
    for e in snap_b.entries:                 # every captured file opens
        snap_b.segment(e.name).close()
    snap_b.close()
    assert pipe._live_snapshots == 0
    assert not pipe._graveyard               # drained exactly once
    sess.close()
