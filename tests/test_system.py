"""End-to-end behaviour tests for the whole system: search engine + LM
training + serving + the paper's headline claims at reduced scale."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig, TrainConfig
from repro.configs.paper_search import smoke
from repro.configs.registry import get_smoke_config
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx
from repro.models import model as M
from repro.serve.step import generate
from repro.train.loop import Trainer


def test_document_search_end_to_end():
    """The paper's primary workload: batched document search returns exact
    best matches (K*L grid, hierarchical top-k, stream-format ingest)."""
    cfg = smoke()
    corpus = corpus_lib.synthesize(300, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=9)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                              backend="jnp")
    idxs = [0, 123, 299]
    qs = [corpus_lib.make_query(corpus, i, cfg.max_query_nnz) for i in idxs]
    res = eng.search(np.stack([q[0] for q in qs]),
                     np.stack([q[1] for q in qs]))
    assert list(res.doc_ids[:, 0]) == idxs
    np.testing.assert_allclose(res.scores[:, 0], 1.0, rtol=1e-5)


def test_train_then_serve_round_trip(tmp_path):
    """Train a smoke LM a few steps, checkpoint, reload, generate."""
    cfg = get_smoke_config("qwen3-4b")
    tc = TrainConfig(model=cfg,
                     opt=OptimizerConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=50),
                     seq_len=32, global_batch=4, checkpoint_every=5,
                     checkpoint_dir=str(tmp_path / "ck"), seed=1)
    ctx = single_device_ctx()
    t = Trainer(tc, ctx, log_fn=lambda s: None)
    t.run(6)
    t.ckpt.wait()

    t2 = Trainer(tc, ctx, log_fn=lambda s: None)   # auto-restores
    assert t2.start_step == 5
    prompt = jnp.asarray(np.arange(8, dtype=np.int32)[None] % cfg.vocab_size)
    out = generate(t2.params, cfg, ctx, prompt, max_new=4, max_len=16)
    assert out.shape == (1, 4)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_stream_format_is_the_storage_path():
    """Corpus built via UCI-style tuples round-trips through the Fig. 8
    stream format and searches correctly."""
    tuples = []
    rng = np.random.default_rng(4)
    for d in range(50):
        for w in rng.choice(500, 10, replace=False):
            tuples.append((d, int(w), int(rng.integers(1, 9))))
    corpus = corpus_lib.from_tuples(tuples, nnz_pad=16)
    assert corpus.n_docs == 50
    cfg = dataclasses.replace(smoke(), vocab_size=512)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                              backend="jnp")
    qi, qv = corpus_lib.make_query(corpus, 17, cfg.max_query_nnz)
    res = eng.search(qi[None], qv[None])
    assert res.doc_ids[0, 0] == 17


def test_batched_queries_match_single_queries():
    """spM x spM == L independent spMV (paper §II.A)."""
    cfg = smoke()
    corpus = corpus_lib.synthesize(128, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=2)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                              backend="jnp")
    idxs = [5, 60, 100]
    qs = [corpus_lib.make_query(corpus, i, cfg.max_query_nnz) for i in idxs]
    qi = np.stack([q[0] for q in qs])
    qv = np.stack([q[1] for q in qs])
    batched = eng.search(qi, qv)
    for l, i in enumerate(idxs):
        single = eng.search(qi[l:l + 1], qv[l:l + 1])
        np.testing.assert_allclose(batched.scores[l], single.scores[0],
                                   rtol=1e-5, atol=1e-6)
