"""_merge_results (vectorized, serving hot path) vs the original
per-row Python loop: exact output equivalence plus the invariants the
docstring promises (dedup, stable tie-breaking, -1/-inf fillers)."""
import numpy as np
from hypothesis_compat import given, settings, strategies as st

from repro.core.engine import SearchResult, _merge_results


def _merge_results_loop(a, b, k):
    """The pre-vectorization reference implementation, verbatim."""
    ids = np.concatenate([a.doc_ids, b.doc_ids], axis=1)
    sc = np.concatenate([a.scores, b.scores], axis=1)
    L = ids.shape[0]
    out_i = np.full((L, k), -1, np.int64)
    out_s = np.full((L, k), -np.inf, np.float32)
    for row in range(L):
        col = 0
        seen = set()
        for j in np.argsort(-sc[row], kind="stable"):
            d = int(ids[row, j])
            if d < 0 or d in seen:
                continue
            seen.add(d)
            out_i[row, col] = d
            out_s[row, col] = sc[row, j]
            col += 1
            if col == k:
                break
    return SearchResult(out_i, out_s)


def _random_result(rng, L, k, id_pool, tie_scores):
    """Candidate sets with heavy duplication, ties, and -1/-inf filler
    (including the adversarial valid-id-with--inf-score corner)."""
    ids = rng.integers(-1, id_pool, (L, k)).astype(np.int64)
    if tie_scores:
        sc = rng.integers(0, 4, (L, k)).astype(np.float32)
    else:
        sc = rng.standard_normal((L, k)).astype(np.float32)
    sc = np.where(ids < 0, -np.inf, sc).astype(np.float32)
    drop = rng.random((L, k)) < 0.1
    sc = np.where(drop, -np.inf, sc).astype(np.float32)  # -inf w/ valid id
    return SearchResult(ids, sc)


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 2**20), l=st.integers(1, 6), k=st.integers(1, 9),
       pool=st.integers(1, 12), ties=st.sampled_from([True, False]))
def test_vectorized_equals_loop(seed, l, k, pool, ties):
    rng = np.random.default_rng(seed)
    a = _random_result(rng, l, k, pool, ties)
    b = _random_result(rng, l, k, pool, ties)
    got = _merge_results(a, b, k)
    want = _merge_results_loop(a, b, k)
    np.testing.assert_array_equal(got.doc_ids, want.doc_ids)
    np.testing.assert_array_equal(got.scores, want.scores)
    assert got.doc_ids.dtype == want.doc_ids.dtype
    assert got.scores.dtype == want.scores.dtype


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**20), l=st.integers(1, 4), k=st.integers(1, 8))
def test_merge_invariants(seed, l, k):
    rng = np.random.default_rng(seed)
    a = _random_result(rng, l, k, 8, True)
    b = _random_result(rng, l, k, 8, True)
    r = _merge_results(a, b, k)
    for row in range(l):
        ids, sc = r.doc_ids[row], r.scores[row]
        real = ids >= 0
        # dedup: every reported doc id appears once
        assert len(set(ids[real].tolist())) == int(real.sum())
        # descending scores over the real prefix, fillers strictly after
        # (elementwise >=, not diff: -inf minus -inf is nan)
        assert np.all(sc[real][:-1] >= sc[real][1:])
        n_real = int(real.sum())
        assert not real[n_real:].any()               # compacted prefix
        np.testing.assert_array_equal(ids[~real], -1)
        np.testing.assert_array_equal(sc[~real], -np.inf)
        # no real candidate was displaced by filler: the merged row holds
        # min(k, #unique valid ids) real entries (a valid id scored -inf
        # still counts — it outranks the -1 filler, never a real score)
        cand = np.concatenate([a.doc_ids[row], b.doc_ids[row]])
        avail = set(cand[cand >= 0].tolist())
        assert n_real == min(k, len(avail))


def test_stable_tie_break_prefers_a_then_input_order():
    """Equal scores: a's candidates come before b's, and within one input
    earlier columns come first (argsort stability contract)."""
    a = SearchResult(np.array([[1, 2]], np.int64),
                     np.array([[5.0, 5.0]], np.float32))
    b = SearchResult(np.array([[3, 4]], np.int64),
                     np.array([[5.0, 5.0]], np.float32))
    r = _merge_results(a, b, 4)
    np.testing.assert_array_equal(r.doc_ids, [[1, 2, 3, 4]])


def test_duplicate_keeps_best_scoring_entry():
    a = SearchResult(np.array([[9, 7]], np.int64),
                     np.array([[3.0, 1.0]], np.float32))
    b = SearchResult(np.array([[7, 9]], np.int64),
                     np.array([[2.0, 0.5]], np.float32))
    r = _merge_results(a, b, 4)
    np.testing.assert_array_equal(r.doc_ids, [[9, 7, -1, -1]])
    np.testing.assert_array_equal(r.scores[0, :2], [3.0, 2.0])
