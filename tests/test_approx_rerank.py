"""Approximate candidate tier + exact re-rank (DESIGN.md §15):
full-pool bit-identity with exhaustive search on every scoring surface,
exact-by-default on every legacy path, the per-query opt-in knobs, the
hoisted filter probe, and the filter false-positive accounting."""
import warnings

import numpy as np
import pytest

from repro.cluster import FlashClusterSession, build_sharded_store
from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.serve.api import Query, QueryOptions
from repro.storage import (BitmapFilter, BloomFilter, FlashSearchSession,
                           FlashStore, QueryProbe)
from repro.storage.filter import build_filter
from repro.storage.store import _corpus_docs

CFG = smoke()


def _build_store(root, docs, docs_per_segment=64, filter_kind="auto"):
    store = FlashStore.create(str(root), vocab_size=CFG.vocab_size,
                              docs_per_segment=docs_per_segment,
                              filter_kind=filter_kind)
    store.append_docs(docs)
    return store


def _queries(corpus, idxs):
    qs = [corpus_lib.make_query(corpus, i, CFG.max_query_nnz) for i in idxs]
    return np.stack([q[0] for q in qs]), np.stack([q[1] for q in qs])


def _assert_same(r, ref):
    np.testing.assert_array_equal(np.asarray(r.doc_ids),
                                  np.asarray(ref.doc_ids))
    np.testing.assert_array_equal(np.asarray(r.scores),
                                  np.asarray(ref.scores))


@pytest.fixture(scope="module")
def corpus():
    return corpus_lib.synthesize(400, CFG.vocab_size, CFG.avg_nnz_per_doc,
                                 CFG.nnz_pad, seed=23)


@pytest.fixture(scope="module")
def docs(corpus):
    return _corpus_docs(corpus)


# ---------------------------------------------------------------------------
# bit-identity: approx with a full pool == exhaustive exact
# ---------------------------------------------------------------------------
def test_approx_full_pool_bit_identical_single_store(tmp_path, corpus, docs):
    store = _build_store(tmp_path / "s", docs)
    qi, qv = _queries(corpus, [3, 71, 200])
    exact = FlashSearchSession(store, CFG, cache_bytes=0)
    ref = exact.search(Query(qi, qv))
    # cache disabled so the posting path actually runs (a warm slab is
    # free exact scoring and wins by design); pool >= any segment size
    res = exact.search(Query(qi, qv),
                       options=QueryOptions(mode="approx",
                                            candidates=len(docs)))
    assert exact.last_stats.approx_segments > 0
    assert exact.last_stats.candidates > 0
    _assert_same(res, ref)
    exact.close()


def test_approx_small_pool_contains_its_own_doc(tmp_path, corpus, docs):
    # a query built from a document's own words must keep that document
    # in its top-k through the approximate tier even at a tiny pool
    store = _build_store(tmp_path / "s", docs)
    sess = FlashSearchSession(store, CFG, cache_bytes=0)
    for idx in (5, 123, 388):
        qi, qv = _queries(corpus, [idx])
        res = sess.search(Query(qi, qv),
                          options=QueryOptions(mode="approx", candidates=4))
        assert sess.last_stats.approx_segments > 0
        assert idx in np.asarray(res.doc_ids)[0]
    sess.close()


def test_approx_full_pool_bit_identical_cluster(tmp_path, corpus, docs):
    qi, qv = _queries(corpus, [9, 42])
    union = FlashSearchSession(_build_store(tmp_path / "u", docs), CFG,
                               cache_bytes=0)
    ref = union.search(Query(qi, qv))
    cl = build_sharded_store(str(tmp_path / "c"), docs, n_shards=3,
                             replicas=1, policy="hash",
                             vocab_size=CFG.vocab_size, docs_per_segment=32)
    sess = FlashClusterSession(cl, CFG, cache_bytes=0)
    res = sess.search(Query(qi, qv),
                      options=QueryOptions(mode="approx",
                                           candidates=len(docs)))
    assert sess.last_stats.approx_segments > 0
    _assert_same(res, ref)
    # per-query exact over the same cluster matches too (mode override)
    res_exact = sess.search(Query(qi, qv),
                            options=QueryOptions(mode="exact"))
    _assert_same(res_exact, ref)
    sess.close()
    union.close()


# ---------------------------------------------------------------------------
# exact is the default everywhere; approx is opt-in
# ---------------------------------------------------------------------------
def test_approx_off_paths_stay_exact_by_default(tmp_path, corpus, docs):
    store = _build_store(tmp_path / "s", docs)
    sess = FlashSearchSession(store, CFG, cache_bytes=0)
    qi, qv = _queries(corpus, [17])
    sess.search(Query(qi, qv))
    assert sess.last_stats.approx_segments == 0
    # bare QueryOptions() must not opt in either
    sess.search(Query(qi, qv), options=QueryOptions())
    assert sess.last_stats.approx_segments == 0
    sess.close()


def test_approx_auto_mode_follows_corpus_size(tmp_path, corpus, docs):
    qi, qv = _queries(corpus, [31])
    store = _build_store(tmp_path / "s", docs)
    big = FlashSearchSession(store, CFG, cache_bytes=0, mode="auto",
                             approx_min_docs=10 ** 9)
    big.search(Query(qi, qv))
    assert big.last_stats.approx_segments == 0     # corpus below floor
    small = FlashSearchSession(store, CFG, cache_bytes=0, mode="auto",
                               approx_min_docs=1)
    small.search(Query(qi, qv))
    assert small.last_stats.approx_segments > 0    # corpus above floor
    _assert_same(small.search(Query(qi, qv),
                              options=QueryOptions(mode="exact")),
                 big.search(Query(qi, qv)))
    big.close()
    small.close()


def test_approx_recall_target_maps_to_pool_width(tmp_path, corpus, docs):
    store = _build_store(tmp_path / "s", docs)
    sess = FlashSearchSession(store, CFG, cache_bytes=0)
    # closer to 1.0 -> wider pool; explicit candidates wins
    _, c_low = sess._query_knobs(QueryOptions(recall_target=0.5))
    _, c_high = sess._query_knobs(QueryOptions(recall_target=0.99))
    assert c_high > c_low >= 4 * CFG.top_k
    _, c_exp = sess._query_knobs(QueryOptions(recall_target=0.99,
                                              candidates=7))
    assert c_exp == 7
    mode, cand = sess._query_knobs(None)
    assert mode is None and cand is None
    sess.close()


def test_approx_mode_validation(tmp_path, corpus, docs):
    store = _build_store(tmp_path / "s", docs)
    with pytest.raises(ValueError, match="mode"):
        FlashSearchSession(store, CFG, mode="fuzzy")
    with pytest.raises(ValueError, match="mode"):
        QueryOptions(mode="fuzzy")
    with pytest.raises(ValueError, match="recall_target"):
        QueryOptions(recall_target=1.5)
    with pytest.raises(ValueError, match="candidates"):
        QueryOptions(candidates=0)


# ---------------------------------------------------------------------------
# legacy positional shim under the mode knob (satellite: migration)
# ---------------------------------------------------------------------------
def test_legacy_positional_warns_once_per_call_site(tmp_path, corpus, docs):
    store = _build_store(tmp_path / "s", docs)
    sess = FlashSearchSession(store, CFG)
    qi, qv = _queries(corpus, [2])
    # warm the compile path first: jax's first trace may mutate the
    # warnings filters, which resets the per-call-site dedup registry
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        sess.search(qi, qv)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("default")     # per-call-site dedup
        for _ in range(3):
            sess.search(qi, qv)              # one call site, three calls
        deps = [w for w in rec if issubclass(w.category, DeprecationWarning)
                and "positional" in str(w.message)]
        assert len(deps) == 1
        sess.search(qi, qv)                  # a second call site
        deps = [w for w in rec if issubclass(w.category, DeprecationWarning)
                and "positional" in str(w.message)]
        assert len(deps) == 2
    sess.close()


def test_legacy_positional_bit_identical_under_mode_knob(tmp_path, corpus,
                                                         docs):
    qi, qv = _queries(corpus, [55, 301])
    store = _build_store(tmp_path / "s", docs)
    for mode in ("exact", "approx", "auto"):
        sess = FlashSearchSession(store, CFG, cache_bytes=0, mode=mode,
                                  candidates=len(docs), approx_min_docs=1)
        typed = sess.search(Query(qi, qv))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            positional = sess.search(qi, qv)
        _assert_same(positional, typed)
        sess.close()


def test_legacy_positional_stays_exact_by_default(tmp_path, corpus, docs):
    store = _build_store(tmp_path / "s", docs)
    sess = FlashSearchSession(store, CFG, cache_bytes=0)
    qi, qv = _queries(corpus, [8])
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        sess.search(qi, qv)
    assert sess.last_stats.approx_segments == 0
    sess.close()


# ---------------------------------------------------------------------------
# hoisted query probe (satellite: one hash pass per query)
# ---------------------------------------------------------------------------
def test_approx_probe_matches_contains_any():
    rng = np.random.default_rng(4)
    vocab = 4096
    for trial in range(20):
        member = rng.choice(vocab, 80, replace=False)
        for f in (build_filter(member, vocab, kind="bitmap"),
                  build_filter(member, vocab, kind="bloom")):
            ids = rng.integers(-1, vocab, size=int(rng.integers(1, 12)))
            probe = QueryProbe(ids)
            assert (f.contains_any_probe(probe)
                    == f.contains_any(ids[ids >= 0]))
    # empty / all-pad probes never match
    for f in (build_filter(member, vocab, kind="bitmap"),
              build_filter(member, vocab, kind="bloom")):
        assert not f.contains_any_probe(QueryProbe(np.asarray([-1, -1])))


def test_approx_probe_hashes_are_reused():
    probe = QueryProbe(np.asarray([3, 7, 7, -1, 11]))
    assert probe.ids.size == 3                # deduped, pads dropped
    assert probe.h1.shape == probe.ids.shape
    assert np.all(probe.h2 % 2 == 1)          # odd -> full-period stride


# ---------------------------------------------------------------------------
# filter false positives made visible (satellite: fp accounting)
# ---------------------------------------------------------------------------
def test_bloom_estimated_fpr_bounds():
    vocab = 4096
    rng = np.random.default_rng(6)
    empty = BloomFilter.build(np.empty(0, np.int64), n_bits=1024, n_hashes=3)
    assert empty.estimated_fpr() == 0.0
    sparse = build_filter(rng.choice(vocab, 16, replace=False), vocab,
                          kind="bloom", n_bits=4096)
    dense = build_filter(rng.choice(vocab, 2048, replace=False), vocab,
                         kind="bloom", n_bits=4096)
    assert 0.0 <= sparse.estimated_fpr() < dense.estimated_fpr() <= 1.0
    # bitmap filters are exact: fpr identically zero
    bm = build_filter(np.asarray([1, 2, 3]), vocab, kind="bitmap")
    assert isinstance(bm, BitmapFilter) and bm.estimated_fpr() == 0.0


def test_filter_fp_segments_counts_pass_but_zero(tmp_path, corpus, docs):
    """Regression for the fp accounting: a segment the Bloom filter
    passes whose every score is zero is a filter false positive and
    must be counted in SearchStats.filter_fp_segments."""
    store = _build_store(tmp_path / "s", docs, filter_kind="bloom")
    sess = FlashSearchSession(store, CFG, cache_bytes=0)
    fp_term = None
    for seg in store.segments():
        present = {w for _, pairs in seg.docs() for w, _ in pairs}
        fp_term = next((t for t in range(CFG.vocab_size)
                        if t not in present
                        and seg.vocab_filter.contains(
                            np.asarray([t])).all()), None)
        if fp_term is not None:
            break
    if fp_term is None:
        pytest.skip("no Bloom false positive in this vocab (fpr too low)")
    qi = np.full((1, CFG.max_query_nnz), -1, np.int32)
    qv = np.zeros((1, CFG.max_query_nnz), np.float32)
    qi[0, 0] = fp_term
    qv[0, 0] = 1.0
    sess.search(Query(qi, qv))
    assert sess.last_stats.filter_fp_segments >= 1
    sess.close()


def test_filter_fp_segments_zero_on_real_matches(tmp_path, corpus, docs):
    store = _build_store(tmp_path / "s", docs)
    sess = FlashSearchSession(store, CFG, cache_bytes=0)
    qi, qv = _queries(corpus, [12])
    sess.search(Query(qi, qv))
    # a doc-derived query scores its own segment nonzero; segments that
    # pass the filter *and* score zero are the only ones counted
    assert (sess.last_stats.filter_fp_segments
            < sess.last_stats.segments_scored)
    sess.close()
