"""End-to-end engine behaviour vs brute-force numpy cosine search."""
import numpy as np
import pytest

from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.core.engine import PatternSearchEngine
from repro.distributed.meshctx import single_device_ctx


def brute_force(corpus, q_ids, q_vals, k):
    V = 1 << 19
    out_ids, out_sc = [], []
    dense_docs = np.zeros((corpus.n_docs, V), np.float32)
    for d in range(corpus.n_docs):
        m = corpus.ids[d] >= 0
        dense_docs[d, corpus.ids[d][m]] = corpus.vals[d][m]
    for l in range(q_ids.shape[0]):
        q = np.zeros(V, np.float32)
        m = q_ids[l] >= 0
        q[q_ids[l][m]] = q_vals[l][m]
        qn = np.linalg.norm(q)
        corr = dense_docs @ q
        denom = np.maximum(corpus.norms * qn, 1e-12)
        cos = np.where(corpus.norms > 0, corr / denom, -np.inf)
        idx = np.argsort(-cos, kind="stable")[:k]
        out_ids.append(corpus.doc_ids[idx])
        out_sc.append(cos[idx])
    return np.stack(out_ids), np.stack(out_sc)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke()
    corpus = corpus_lib.synthesize(200, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=11)
    ctx = single_device_ctx()
    eng = PatternSearchEngine(corpus, cfg, ctx, backend="jnp")
    return cfg, corpus, eng


def _queries(corpus, cfg, idxs):
    qs = [corpus_lib.make_query(corpus, i, cfg.max_query_nnz) for i in idxs]
    return (np.stack([q[0] for q in qs]), np.stack([q[1] for q in qs]))


def test_self_search_returns_self(setup):
    cfg, corpus, eng = setup
    qi, qv = _queries(corpus, cfg, [7])
    r = eng.search(qi, qv)
    assert r.doc_ids[0, 0] == corpus.doc_ids[7]
    np.testing.assert_allclose(r.scores[0, 0], 1.0, rtol=1e-5)


def test_matches_brute_force(setup):
    cfg, corpus, eng = setup
    qi, qv = _queries(corpus, cfg, [3, 50, 120])
    r = eng.search(qi, qv)
    want_ids, want_sc = brute_force(corpus, qi, qv, cfg.top_k)
    np.testing.assert_allclose(r.scores, want_sc, rtol=1e-4, atol=1e-5)
    # ids may permute within score ties; compare score-aligned sets
    for l in range(3):
        assert set(r.doc_ids[l][r.scores[l] > 0.99]) <= set(want_ids[l])


def test_pallas_backend_agrees(setup):
    cfg, corpus, eng = setup
    eng_k = PatternSearchEngine(corpus, cfg, single_device_ctx(),
                                backend="pallas")
    qi, qv = _queries(corpus, cfg, [3, 50])
    a = eng.search(qi, qv)
    b = eng_k.search(qi, qv)
    np.testing.assert_allclose(a.scores, b.scores, rtol=1e-4, atol=1e-5)


def test_streaming_equals_resident(setup):
    cfg, corpus, eng = setup
    qi, qv = _queries(corpus, cfg, [3, 50])
    half = corpus.n_docs // 2
    import dataclasses
    slab1 = corpus_lib.Corpus(corpus.doc_ids[:half], corpus.ids[:half],
                              corpus.vals[:half], corpus.norms[:half])
    slab2 = corpus_lib.Corpus(corpus.doc_ids[half:], corpus.ids[half:],
                              corpus.vals[half:], corpus.norms[half:])
    r_stream = eng.search_streaming(qi, qv, [slab1, slab2])
    r_res = eng.search(qi, qv)
    np.testing.assert_allclose(np.sort(r_stream.scores, 1),
                               np.sort(r_res.scores, 1), rtol=1e-4, atol=1e-5)


def test_topk_exceeding_real_docs_never_leaks_padding():
    """Regression: with top_k > n_docs the padding rows added by
    pad_docs_to (doc_id -1, zero norm) used to be able to surface (and
    top_k > the per-shard row count crashed lax.top_k outright). Now
    every surplus slot is the (-1, -inf) no-result sentinel and all
    finite-score entries are real documents."""
    import dataclasses
    cfg = dataclasses.replace(smoke(), top_k=8)
    corpus = corpus_lib.synthesize(3, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=1)
    eng = PatternSearchEngine(corpus, cfg, single_device_ctx(), backend="jnp")
    qi, qv = _queries(corpus, cfg, [0])
    for r in (eng.search(qi, qv),
              eng.search_streaming(qi, qv, iter([corpus.slice_rows(0, 2),
                                                 corpus.slice_rows(2, 3)]))):
        finite = np.isfinite(r.scores[0])
        assert set(r.doc_ids[0][finite]) == {0, 1, 2}
        assert (r.doc_ids[0][~finite] == -1).all()
        assert np.isneginf(r.scores[0][~finite]).all()
        assert r.doc_ids.shape == (1, cfg.top_k)


def test_protein_and_subgraph_corpora():
    rng = np.random.default_rng(0)
    seqs = ["".join(rng.choice(list(corpus_lib.AMINO), 40)) for _ in range(20)]
    pc = corpus_lib.proteins_corpus(seqs, nnz_pad=64)
    assert pc.n_docs == 20 and (pc.norms > 0).all()
    graphs = [[(int(rng.integers(50)), int(rng.integers(50)))
               for _ in range(15)] for _ in range(10)]
    gc = corpus_lib.subgraphs_corpus(graphs, n_labels=64, nnz_pad=32)
    assert gc.n_docs == 10
    # self-search finds the right protein (3-mer vocab is 20^3 = 8000)
    import dataclasses
    cfg = dataclasses.replace(smoke(), vocab_size=8000)
    eng = PatternSearchEngine(pc, cfg, single_device_ctx(), backend="jnp")
    qi, qv = corpus_lib.make_query(pc, 4, cfg.max_query_nnz)
    r = eng.search(qi[None], qv[None])
    assert r.doc_ids[0, 0] == 4
