"""SLO objectives and burn-state accounting (DESIGN.md §8.4): builder
validation, latency/availability good-fraction math, the
ok -> burning -> exhausted transitions under a synthetic latency step,
recovery semantics, and the published gauges."""
import pytest

from repro.obs import Obs, MetricsRegistry
from repro.obs.slo import (SLOMonitor, SLObjective, availability_slo,
                           default_slos, latency_slo,
                           STATE_BURNING, STATE_EXHAUSTED, STATE_OK)
from tests.test_obs_window import FakeClock


def _obs(clock, window_s=10.0, slices=5):
    return Obs(registry=MetricsRegistry(window_s=window_s,
                                        window_slices=slices, clock=clock))


# -- objective declaration ---------------------------------------------

def test_builders_and_validation():
    o = latency_slo("store-latency", threshold_ms=250.0, target=0.99,
                    surface="store")
    assert o.kind == "latency" and o.threshold_ms == 250.0
    assert o.label_dict == {"surface": "store"}
    a = availability_slo("cluster-avail", target=0.999, surface="cluster")
    assert a.error_metric == "query_errors_total"
    with pytest.raises(ValueError):
        latency_slo("bad", threshold_ms=10.0, target=0.0)
    with pytest.raises(ValueError):
        latency_slo("bad", threshold_ms=10.0, target=1.5)
    with pytest.raises(ValueError):
        SLObjective(name="x", kind="nonsense", metric="m", labels=(),
                    target=0.9)
    stock = default_slos("store", latency_ms=100.0)
    assert [s.kind for s in stock] == ["latency", "availability"]


def test_no_traffic_is_ok():
    obs = _obs(FakeClock())
    mon = SLOMonitor(obs, default_slos("store"))
    for st in mon.evaluate():
        assert st.state == STATE_OK
        assert st.good_fraction is None
        assert st.burn_rate == 0.0
        assert st.window_events == 0


# -- the latency-step transition ---------------------------------------

def test_latency_step_ok_to_burning_to_recovered():
    clk = FakeClock()
    obs = _obs(clk)
    mon = SLOMonitor(obs, [latency_slo(
        "store-latency", threshold_ms=100.0, target=0.90, surface="store")])
    h = obs.registry.histogram("query_ms", surface="store")

    for _ in range(1000):         # healthy: everything under threshold
        h.observe(10.0)
    (st,) = mon.evaluate()
    assert st.state == STATE_OK
    assert st.good_fraction == pytest.approx(1.0)

    clk.advance(20.0)             # healthy burst ages out of the window
    for _ in range(170):          # the synthetic latency step: 15% slow
        h.observe(10.0)
    for _ in range(30):
        h.observe(5000.0)
    (st,) = mon.evaluate()
    assert st.state == STATE_BURNING
    assert st.window_events == 200
    # window bad fraction 30/200 vs allowed 10% -> burn 1.5; lifetime
    # bad 30/1200 -> budget 1 - 0.025/0.10 = 0.75, still in budget
    assert st.burn_rate == pytest.approx(0.15 / 0.10, rel=1e-6)
    assert st.budget_remaining == pytest.approx(0.75, rel=1e-6)

    clk.advance(50.0)             # the step ages out of the window...
    for _ in range(100):
        h.observe(10.0)
    (st,) = mon.evaluate()
    assert st.state == STATE_OK   # ...and the burn state recovers
    assert st.good_fraction == pytest.approx(1.0)
    # ...but the lifetime budget stays spent (error budgets accumulate)
    assert st.budget_remaining < 1.0


def test_sustained_burn_exhausts_budget_and_stays_exhausted():
    clk = FakeClock()
    obs = _obs(clk)
    mon = SLOMonitor(obs, [latency_slo(
        "tight", threshold_ms=1.0, target=0.99, surface="store")])
    h = obs.registry.histogram("query_ms", surface="store")
    for _ in range(100):          # every event bad vs a 1% allowance
        h.observe(500.0)
    (st,) = mon.evaluate()
    assert st.state == STATE_EXHAUSTED
    assert st.budget_remaining <= 0.0
    clk.advance(100.0)            # idle window: burn 0, budget still gone
    (st,) = mon.evaluate()
    assert st.state == STATE_EXHAUSTED
    assert st.burn_rate == 0.0


def test_target_one_edge():
    # target=1.0 allows zero bad events: one failure is instant
    # exhaustion, zero failures stay ok (no division by the 0 allowance)
    clk = FakeClock()
    obs = _obs(clk)
    mon = SLOMonitor(obs, [latency_slo(
        "perfect", threshold_ms=100.0, target=1.0, surface="store")])
    h = obs.registry.histogram("query_ms", surface="store")
    h.observe(1.0)
    (st,) = mon.evaluate()
    assert st.state == STATE_OK
    h.observe(5000.0)
    (st,) = mon.evaluate()
    assert st.state == STATE_EXHAUSTED


# -- availability ------------------------------------------------------

def test_availability_counts_errors():
    clk = FakeClock()
    obs = _obs(clk)
    mon = SLOMonitor(obs, [availability_slo(
        "cluster-avail", target=0.90, surface="cluster")])
    total = obs.registry.counter("queries_total", surface="cluster")
    errs = obs.registry.counter("query_errors_total", surface="cluster")
    total.inc(100)
    (st,) = mon.evaluate()
    assert st.state == STATE_OK and st.good_fraction == pytest.approx(1.0)
    errs.inc(50)                  # 50% errors vs 10% allowance
    (st,) = mon.evaluate()
    assert st.state in (STATE_BURNING, STATE_EXHAUSTED)
    assert st.good_fraction == pytest.approx(0.5)
    assert st.burn_rate == pytest.approx(5.0)
    clk.advance(100.0)            # errors age out of the window
    total.inc(100)
    (st,) = mon.evaluate()
    assert st.good_fraction == pytest.approx(1.0)
    assert st.burn_rate == 0.0


# -- gauge publication -------------------------------------------------

def test_evaluate_publishes_gauges_and_dict():
    clk = FakeClock()
    obs = _obs(clk)
    mon = SLOMonitor(obs, [latency_slo(
        "store-latency", threshold_ms=100.0, target=0.90, surface="store")])
    h = obs.registry.histogram("query_ms", surface="store")
    for _ in range(10):
        h.observe(5000.0)
    (st,) = mon.evaluate()
    reg = obs.registry
    assert reg.gauge("slo_state", slo="store-latency").value == 2.0
    assert reg.gauge("slo_burn_rate", slo="store-latency").value >= 1.0
    assert reg.gauge("slo_good_fraction",
                     slo="store-latency").value == pytest.approx(0.0)
    d = st.to_dict()
    assert d["name"] == "store-latency" and d["state"] == STATE_EXHAUSTED
    assert set(d) >= {"kind", "target", "good_fraction", "burn_rate",
                      "budget_remaining", "window_events",
                      "lifetime_events", "detail"}
    text = reg.to_prometheus()
    assert 'repro_slo_state{slo="store-latency"} 2' in text
