"""Deadline-aware partial gather (DESIGN.md §7.3): a budget-bound
scatter returns the best-effort merge of the responsive shards, flagged
and attributed — and is bit-identical to the full gather whenever every
shard answers in time. Plus the structured ClusterSearchError contract."""
import time

import numpy as np
import pytest

from repro.cluster import (ClusterSearchError, FlashClusterSession,
                           build_sharded_store)
from repro.cluster.router import ClusterStats
from repro.configs.paper_search import smoke
from repro.core import corpus as corpus_lib
from repro.serve import Query, QueryOptions
from repro.storage import FlashSearchSession, FlashStore
from repro.storage.store import _corpus_docs


class _Slow:
    def __init__(self, inner, delay_s):
        self._inner = inner
        self._delay = delay_s

    def search(self, *a, **k):
        time.sleep(self._delay)
        return self._inner.search(*a, **k)

    def __getattr__(self, name):
        return getattr(self._inner, name)


class _Boom:
    def __init__(self, inner):
        self._inner = inner                 # may be a never-opened slot

    def search(self, *a, **k):
        raise OSError("replica storage gone")

    def close(self):
        if self._inner is not None:
            self._inner.close()

    def __getattr__(self, name):
        return getattr(self._inner, name)


def _setup(tmp_path, cfg, n_shards=2, replicas=1):
    corpus = corpus_lib.synthesize(150, cfg.vocab_size, cfg.avg_nnz_per_doc,
                                   cfg.nnz_pad, seed=13)
    docs = _corpus_docs(corpus)
    cl = build_sharded_store(str(tmp_path / "c"), docs, n_shards=n_shards,
                             replicas=replicas, policy="hash",
                             vocab_size=cfg.vocab_size, docs_per_segment=16)
    union = FlashStore.create(str(tmp_path / "u"),
                              vocab_size=cfg.vocab_size, docs_per_segment=64)
    union.append_docs(docs)
    return (corpus, FlashClusterSession(cl, cfg),
            FlashSearchSession(union, cfg))


def _q(corpus, cfg, idx=9):
    qi, qv = corpus_lib.make_query(corpus, idx, cfg.max_query_nnz)
    return Query(qi[None], qv[None])


def test_partial_gather_drops_straggler_and_flags_it(tmp_path):
    cfg = smoke()
    corpus, sess, union = _setup(tmp_path, cfg)
    try:
        q = _q(corpus, cfg)
        sess.search_typed(q)                # warm: every primary open
        sess.router._sessions[1][0] = _Slow(sess.router._sessions[1][0], 0.8)
        t0 = time.monotonic()
        resp = sess.search(q, options=QueryOptions(
            deadline_ms=80.0, allow_partial=True))
        wall = time.monotonic() - t0
        assert wall < 0.7, f"gather did not respect the budget " \
                           f"({wall*1e3:.0f}ms)"
        assert resp.stats.partial and resp.stats.shards_missing == (1,)
        st = sess.last_stats
        assert st.partial and st.shards_missing == (1,)
        # the merge degraded to exactly the responsive shard's answer —
        # intact, nothing invented
        shard0 = sess.router._session(0, 0).search_typed(q)
        np.testing.assert_array_equal(resp.doc_ids, shard0.doc_ids)
        np.testing.assert_array_equal(resp.scores, shard0.scores)
        assert (resp.doc_ids >= 0).any()    # shard 0 did contribute
    finally:
        sess.close()
        union.close()


def test_partial_gather_bit_identical_when_all_shards_respond(tmp_path):
    cfg = smoke()
    corpus, sess, union = _setup(tmp_path, cfg)
    try:
        q = _q(corpus, cfg, idx=4)
        ref = union.search_typed(_q(corpus, cfg, idx=4))
        plain = sess.search_typed(q)
        resp = sess.search(q, options=QueryOptions(
            deadline_ms=60_000.0, allow_partial=True))
        assert not resp.stats.partial and resp.stats.shards_missing == ()
        np.testing.assert_array_equal(resp.doc_ids, plain.doc_ids)
        np.testing.assert_array_equal(resp.scores, plain.scores)
        np.testing.assert_array_equal(resp.doc_ids, ref.doc_ids)
        np.testing.assert_array_equal(resp.scores, ref.scores)
    finally:
        sess.close()
        union.close()


def test_partial_gather_every_shard_missing_returns_sentinel(tmp_path):
    cfg = smoke()
    corpus, sess, union = _setup(tmp_path, cfg)
    try:
        q = _q(corpus, cfg)
        sess.search_typed(q)
        for s in range(2):
            sess.router._sessions[s][0] = _Slow(
                sess.router._sessions[s][0], 0.6)
        resp = sess.search(q, options=QueryOptions(
            deadline_ms=40.0, allow_partial=True))
        assert resp.stats.partial
        assert resp.stats.shards_missing == (0, 1)
        # a well-formed [L, k] no-result answer, never a hang or a crash
        assert resp.doc_ids.shape == (1, cfg.top_k)
        assert (resp.doc_ids == -1).all()
        assert np.isneginf(resp.scores).all()
    finally:
        sess.close()
        union.close()


def test_partial_consent_turns_shard_failure_into_missing(tmp_path):
    """With allow_partial, a *failed* shard (every replica dead) degrades
    to a missing shard instead of failing the query."""
    cfg = smoke()
    corpus, sess, union = _setup(tmp_path, cfg)
    try:
        q = _q(corpus, cfg)
        sess.search_typed(q)
        sess.router._sessions[0][0] = _Boom(sess.router._sessions[0][0])
        resp = sess.search(q, options=QueryOptions(
            deadline_ms=60_000.0, allow_partial=True))
        assert resp.stats.partial and resp.stats.shards_missing == (0,)
        # without consent the same failure raises (the legacy contract)
        with pytest.raises(ClusterSearchError):
            sess.search_typed(q)
    finally:
        sess.close()
        union.close()


def test_partial_failure_without_consent_raises_structured_error(tmp_path):
    cfg = smoke()
    corpus, sess, union = _setup(tmp_path, cfg, replicas=2)
    try:
        q = _q(corpus, cfg)
        sess.search_typed(q)
        for r in range(2):
            sess.router._sessions[1][r] = _Boom(sess.router._sessions[1][r])
        with pytest.raises(ClusterSearchError) as ei:
            sess.search_typed(q)
        e = ei.value
        assert e.shard == 1
        assert set(e.replica_errors) == {0, 1}
        assert all("OSError" in s for s in e.replica_errors.values())
        assert hasattr(e, "trace_id")       # None unless tracing sampled
        assert "shard 1" in str(e)
    finally:
        sess.close()
        union.close()


def test_partial_stats_fields_default_off():
    st = ClusterStats([None])
    assert not st.partial and st.shards_missing == ()
    assert st.hedges == 0 and st.hedge_wins == 0
